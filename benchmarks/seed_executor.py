"""Seed-executor replica: the pre-PR1 polling scheduler, kept ONLY as the
benchmark baseline for ``bench_scheduler_overhead``.

Reproduces the seed repo's ``Executor`` dispatch faithfully at the same API
surface the event-driven executor now exposes (``submit(header, kind, pv,
code, name)``):

* one pending deque; every wakeup scans it O(pending) for a ready task;
* readiness is re-evaluated via the header condition each scan;
* wakeups arrive as counter-change *broadcasts* — a listener registered on
  every header this executor has tasks for pokes it on any lv/ltv/instance
  change, regardless of whether any parked condition is affected;
* a 50 ms ``wait(timeout=...)`` liveness backstop covers lost pokes.

``patched()`` swaps this class in for the real executor inside
``repro.core.registry`` so an identical Eigenbench run isolates exactly the
scheduling-core difference.
"""
from __future__ import annotations

import contextlib
import threading
import traceback
from collections import deque
from typing import Callable, List, Optional

from repro.core.api import TransactionError
from repro.core.versioning import VersionHeader


class _PollTask:
    __slots__ = ("condition", "code", "done", "error", "name")

    def __init__(self, condition: Callable[[], bool], code: Callable[[], None],
                 name: str):
        self.condition = condition
        self.code = code
        self.done = threading.Event()
        self.error: Optional[BaseException] = None
        self.name = name

    def join(self) -> None:
        self.done.wait()
        if self.error is not None:
            if isinstance(self.error, TransactionError):
                raise self.error
            raise RuntimeError(f"executor task {self.name} failed") from self.error

    def run_if_ready(self) -> bool:
        if not self.condition():
            return False
        try:
            self.code()
        except BaseException as e:  # noqa: BLE001 - propagate via join()
            self.error = e
            if not isinstance(e, TransactionError):
                traceback.print_exc()
        finally:
            self.done.set()
        return True


class PollingExecutor:
    """The seed's poll-and-scan executor behind the new submit signature."""

    def __init__(self, name: str = "executor", workers: int = 1):
        self._lock = threading.Lock()
        self._wakeup = threading.Condition(self._lock)
        self._pending: deque[_PollTask] = deque()
        self._stopping = False
        self._listened: set = set()
        self._threads: List[threading.Thread] = []
        for i in range(max(1, workers)):
            t = threading.Thread(target=self._loop, name=f"{name}-{i}", daemon=True)
            t.start()
            self._threads.append(t)

    def poke(self) -> None:
        with self._lock:
            self._wakeup.notify_all()

    def _ensure_listener(self, header: VersionHeader) -> None:
        # Seed behavior: every shared object's header broadcast-pokes its
        # node executor on any counter change.
        with self._lock:
            if header in self._listened:
                return
            self._listened.add(header)
        header.add_listener(self.poke)

    def submit(self, header: VersionHeader, kind: str, pv: int,
               code: Callable[[], None], name: str = "task") -> _PollTask:
        if kind == "termination":
            condition = lambda: header.termination_ready(pv)  # noqa: E731
        else:
            condition = lambda: header.access_ready(pv)       # noqa: E731
        self._ensure_listener(header)
        task = _PollTask(condition, code, name)
        with self._lock:
            if self._stopping:
                raise RuntimeError("executor is shut down")
            self._pending.append(task)
            self._wakeup.notify_all()
        return task

    def _loop(self) -> None:
        while True:
            with self._lock:
                if self._stopping and not self._pending:
                    return
                task: Optional[_PollTask] = None
                # Scan for a ready task; preserve FIFO among non-ready ones.
                for _ in range(len(self._pending)):
                    cand = self._pending.popleft()
                    try:
                        ready = cand.condition()
                    except BaseException as e:  # noqa: BLE001
                        cand.error = e
                        cand.done.set()
                        continue
                    if ready:
                        task = cand
                        break
                    self._pending.append(cand)
                if task is None:
                    if self._stopping:
                        return
                    # Counter changes poke us; timeout is a liveness backstop.
                    self._wakeup.wait(timeout=0.05)
                    continue
            task.run_if_ready()

    def shutdown(self) -> None:
        with self._lock:
            self._stopping = True
            self._wakeup.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)

    def pending_count(self) -> int:
        with self._lock:
            return len(self._pending)


@contextlib.contextmanager
def patched():
    """Run Eigenbench with nodes built on the seed polling executor."""
    import repro.core.registry as registry

    orig = registry.Executor
    registry.Executor = PollingExecutor
    try:
        yield
    finally:
        registry.Executor = orig
