"""Distributed Eigenbench (paper §4.2, Figs. 10-13).

Eigenbench [Hong et al., IISWC'10] as distributed by Siek & Wojciechowski:
three arrays of reference-cell shared objects per node —

* **hot**: shared by all clients (contended, TM-controlled),
* **mild**: partitioned per client (TM-controlled, conflict-free),
* **cold**: partitioned per client, accessed non-transactionally,

with per-scenario read:write ratios, operation locality (probability of
re-picking from a history window), and a fixed per-operation service time
(the paper uses ~3 ms to model complex CF computations; scaled down here by
default so the matrix fits CI — the *relative* framework ordering is what
the reproduction validates).

Frameworks under test (paper §4.1): Atomic RMI 2 (OptSVA-CF), Atomic RMI
(SVA), Mutex/R-W locks × S2PL/2PL, GLock, and a TFA-style optimistic
baseline standing in for HyFlow2. Threads stand in for client nodes.

Two transports (``--transport``): ``inproc`` — Registry nodes with
*simulated* network delay stand in for hosts; ``tcp`` — each node is a real
server subprocess (``repro.net``, DESIGN.md §3.1) and every operation is an
honest RPC to its home node (``network_delay_ms`` is ignored: latency is
real). Only ``optsva-cf`` runs over TCP.
"""
from __future__ import annotations

import argparse
import os
import random
import sys
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import (AbortError, LockTransaction, Mode, Registry,
                        SvaTransaction, TfaTransaction, Transaction, access)


class RefCell:
    """A reference cell whose operations cost ``op_time`` (CF-model work).

    ``op_time`` is carried per instance (with the class attribute as the
    in-process default) so that cells shipped to a TCP node server burn
    their service time *on the home node* — the CF model's point.
    """

    op_time: float = 0.0  # class-level default; set by the in-proc harness

    def __init__(self, value: int = 0, op_time: Optional[float] = None):
        self.value = value
        if op_time is not None:
            self.op_time = op_time

    @access(Mode.READ)
    def read(self) -> int:
        if self.op_time:
            time.sleep(self.op_time)
        return self.value

    @access(Mode.WRITE)
    def write(self, v: int) -> None:
        if self.op_time:
            time.sleep(self.op_time)
        self.value = v

    def __tx_snapshot__(self) -> "RefCell":
        # O(1) snapshot protocol: the state is one immutable int, so a
        # shallow clone replaces the deepcopy on every checkpoint/buffer.
        return RefCell(self.value, self.op_time or None)


class HotCell(RefCell):
    """A reference cell whose increments form a commuting method class
    (DESIGN.md §12): ``add`` deltas merge at the home node without
    version-gated dispensing — the hot-key workload's primitive."""

    @access(Mode.WRITE, commutes="add")
    def add(self, d: int) -> None:
        if self.op_time:
            time.sleep(self.op_time)
        self.value += d

    def __tx_snapshot__(self) -> "HotCell":
        return HotCell(self.value, self.op_time or None)


@dataclass
class EigenConfig:
    nodes: int = 4
    clients_per_node: int = 4
    arrays_per_node: int = 10          # objects in each array type per node
    txns_per_client: int = 5
    hot_ops: int = 10
    mild_ops: int = 0
    read_pct: float = 0.9              # fraction of reads among ops
    locality: float = 0.5
    history: int = 5
    op_time_ms: float = 0.3
    network_delay_ms: float = 0.0
    seed: int = 42
    #: ``mix`` — the classic ratio-mix plans; ``bank`` — long-chain bank
    #: transfers: each transaction walks ``chain_len`` accounts moving a
    #: balance along the chain (read-modify-write per hop, consecutive
    #: ops per object — the operation-fusion hot path); ``hotkey`` —
    #: Zipfian hot-key increments: every transaction bumps one
    #: Zipf-picked hot cell ``hot_ops`` times (the commute workload:
    #: ``commute=True`` declares the bumps commute-restricted and they
    #: merge as deltas, ``commute=False`` runs the identical plan through
    #: exact version-gated accesses — the pre-§12 message plan).
    workload: str = "mix"
    chain_len: int = 4
    commute: bool = True               # hotkey workload only
    zipf_s: float = 1.5                # hotkey skew exponent


@dataclass
class Result:
    framework: str
    throughput_ops: float              # transactional shared-data ops / sec
    aborts: int
    retries: int
    commits: int
    abort_rate_pct: float
    wall_s: float
    waits: int = 0                     # actual blocking waits, all frameworks
    # -- wire metrics (tcp transport only; 0.0 in-proc) ----------------------
    rpcs_per_txn: float = 0.0          # client round trips per committed txn
    oneways_per_txn: float = 0.0       # client one-way messages per txn
    handoffs_per_txn: float = 0.0      # replies crossing a thread handoff
    replication_oneways_per_txn: float = 0.0   # server->follower one-ways
    # -- membership metrics (sim transport only; 0.0 elsewhere) --------------
    migrations_per_txn: float = 0.0    # §10 lease handoffs completed
    lease_renews_per_txn: float = 0.0  # §10 lease-renewal one-ways sent
    # -- durability metrics (sim transport only; 0.0 elsewhere) --------------
    wal_appends_per_txn: float = 0.0   # §11 ledger records per committed txn
    fsync_batches_per_txn: float = 0.0 # §11 group-commit flushes per txn
    # -- commute metrics (sim transport only; 0.0 elsewhere) ------------------
    commute_oneways_per_txn: float = 0.0  # §12 deltas shipped one-way
    merged_deltas_per_txn: float = 0.0    # §12 deltas folded under merge lock


Step = Tuple[Any, str, Optional[int]]  # (shared_obj, "read"/"write", value)


def _gen_plan(rng: random.Random, cfg: EigenConfig, hot: List, mild: List
              ) -> List[Step]:
    """One transaction's operation list (generated a priori: this is the
    a-priori knowledge the versioning algorithms feed on)."""
    steps: List[Step] = []
    history: List[Any] = []

    def pick(pool: List) -> Any:
        if history and rng.random() < cfg.locality:
            obj = rng.choice(history[-cfg.history:])
        else:
            obj = rng.choice(pool)
        history.append(obj)
        return obj

    ops = (["hot"] * cfg.hot_ops) + (["mild"] * cfg.mild_ops)
    rng.shuffle(ops)
    for kind in ops:
        pool = hot if kind == "hot" else mild
        obj = pick(pool)
        if rng.random() < cfg.read_pct:
            steps.append((obj, "read", None))
        else:
            steps.append((obj, "write", rng.randrange(1 << 16)))
    return steps


def _gen_bank_plan(rng: random.Random, cfg: EigenConfig, hot: List,
                   mild: List, history: Optional[List] = None) -> List[Step]:
    """Long-chain "bank transfer": move a value along ``chain_len``
    distinct accounts — read the source, write it back, read the next,
    write it, ... Every hop is a consecutive read+write pair on one
    object, the exact shape the §2.8 operation-fusion path batches into
    single ``txn_call_batch`` RPCs. ``history`` is the *per-client*
    window spanning this client's previous transactions — ``locality``
    biases each chain toward it (accounts already in the current chain
    are excluded: chain hops are distinct). ``read_pct`` is ignored (the
    chain fixes the 1:1 ratio)."""
    pool = list(hot) + list(mild)
    if history is None:
        history = []

    def pick_distinct(taken):
        for _ in range(64):
            window = [o for o in history[-cfg.history:] if o not in taken]
            if window and rng.random() < cfg.locality:
                obj = rng.choice(window)
            else:
                obj = rng.choice(pool)
            if obj not in taken:
                history.append(obj)
                return obj
        for obj in pool:        # tiny pools: fall back to a linear sweep
            if obj not in taken:
                history.append(obj)
                return obj
        return None

    chain: List[Any] = []
    for _ in range(min(cfg.chain_len, len(pool))):
        obj = pick_distinct(chain)
        if obj is None:
            break
        chain.append(obj)
    steps: List[Step] = []
    for obj in chain:
        steps.append((obj, "read", None))
        steps.append((obj, "write", rng.randrange(1 << 16)))
    return steps


def _zipf_weights(n: int, s: float) -> List[float]:
    return [1.0 / (i + 1) ** s for i in range(n)]


def _gen_hotkey_plan(rng: random.Random, cfg: EigenConfig, hot: List
                     ) -> List[Step]:
    """Zipfian hot-key increments: pick ONE hot cell (Zipf over the pool,
    so the head cell draws most transactions across all clients) and bump
    it ``hot_ops`` times. Every step is an ``add`` — a declared commuting
    WRITE — so the commute-restricted execution ships the whole
    transaction as mergeable deltas; the exact execution runs the same
    plan through version-gated dispensing."""
    weights = _zipf_weights(len(hot), cfg.zipf_s)
    total = sum(weights)
    x = rng.random() * total
    idx = len(hot) - 1
    for i, w in enumerate(weights):
        x -= w
        if x <= 0:
            idx = i
            break
    obj = hot[idx]
    return [(obj, "add", rng.randrange(1, 100)) for _ in range(cfg.hot_ops)]


def _plan_counts(steps: Sequence[Step]) -> Dict[Any, Tuple[int, int]]:
    counts: Dict[Any, Tuple[int, int]] = {}
    for obj, op, _ in steps:
        r, w = counts.get(obj, (0, 0))
        counts[obj] = (r + 1, w) if op == "read" else (r, w + 1)
    return counts


def _last_access_index(steps: Sequence[Step]) -> Dict[Any, int]:
    last = {}
    for i, (obj, _, _) in enumerate(steps):
        last[obj] = i
    return last


# --------------------------------------------------------------------------- #
# Per-framework executors: run one transaction given its op plan              #
# --------------------------------------------------------------------------- #
def run_optsva(reg: Registry, steps: List[Step], stats: Dict) -> None:
    t = Transaction(reg)
    counts = _plan_counts(steps)
    proxies = {obj: t.accesses(obj, r, w, 0) for obj, (r, w) in counts.items()}

    def body(t):
        # Consecutive same-object steps go through invoke_many: the
        # a-priori plan makes the run visible, and the remote transport
        # fuses it into one txn_call_batch RPC (operation fusion, §2.8);
        # semantics are identical to per-op invocation either way.
        i, n = 0, len(steps)
        while i < n:
            obj = steps[i][0]
            j = i + 1
            while j < n and steps[j][0] is obj:
                j += 1
            if j - i == 1:
                _o, op, val = steps[i]
                p = proxies[obj]
                p.read() if op == "read" else getattr(p, op)(val)
            else:
                t.invoke_many(proxies[obj],
                              [("read", (), {}) if op == "read"
                               else (op, (val,), {})
                               for _o, op, val in steps[i:j]])
            i = j

    _run_pessimistic(t, body, stats)


def run_optsva_commute(reg: Registry, steps: List[Step], stats: Dict) -> None:
    """The §12 commute-restricted execution of an all-``add`` plan: the
    transaction promises to touch each object only through its commuting
    class, skips version-gated dispensing, and its invocations merge as
    deltas at the home node."""
    t = Transaction(reg)
    counts: Dict[Any, int] = {}
    for obj, _op, _v in steps:
        counts[obj] = counts.get(obj, 0) + 1
    proxies = {obj: t.commutes(obj, n) for obj, n in counts.items()}

    def body(t):
        for obj, _op, val in steps:
            proxies[obj].add(val)

    _run_pessimistic(t, body, stats)


def run_sva(reg: Registry, steps: List[Step], stats: Dict) -> None:
    t = SvaTransaction(reg)
    counts = _plan_counts(steps)
    proxies = {obj: t.accesses(obj, r + w) for obj, (r, w) in counts.items()}

    def body(t):
        for obj, op, val in steps:
            p = proxies[obj]
            p.read() if op == "read" else p.write(val)

    _run_pessimistic(t, body, stats)


def _run_pessimistic(t, body, stats: Dict) -> None:
    try:
        t.start(body)
        stats["commits"] += 1
    except AbortError:
        stats["aborts"] += 1
    finally:
        stats["waits"] += t.stats.waits


def make_lock_runner(kind: str, strict: bool) -> Callable:
    def run(reg: Registry, steps: List[Step], stats: Dict) -> None:
        t = LockTransaction(reg, kind=kind, strict=strict)
        counts = _plan_counts(steps)
        will_write = {obj: w > 0 for obj, (r, w) in counts.items()}
        proxies = {obj: (t.writes(obj) if ww else t.reads(obj))
                   for obj, ww in will_write.items()}
        last = _last_access_index(steps)

        def body(t):
            for i, (obj, op, val) in enumerate(steps):
                p = proxies[obj]
                p.read() if op == "read" else p.write(val)
                if not strict and last[obj] == i:
                    t.done(p)   # programmer-determined last access (2PL)

        t.start(body)
        stats["commits"] += 1
        stats["waits"] += t.stats.waits

    return run


def run_tfa(reg: Registry, steps: List[Step], stats: Dict) -> None:
    t = TfaTransaction(reg)
    proxies = {obj: t.accesses(obj) for obj in {s[0] for s in steps}}

    def body(t):
        for obj, op, val in steps:
            p = proxies[obj]
            p.read() if op == "read" else p.write(val)

    t.start(body)
    stats["commits"] += 1
    stats["aborts"] += t.stats.aborts
    stats["retries"] += t.stats.retries
    stats["waits"] += t.stats.waits


FRAMEWORKS: Dict[str, Callable] = {
    "optsva-cf": run_optsva,                       # Atomic RMI 2
    "sva": run_sva,                                # Atomic RMI
    "tfa": run_tfa,                                # HyFlow2 stand-in
    "mutex-s2pl": make_lock_runner("mutex", True),
    "mutex-2pl": make_lock_runner("mutex", False),
    "rw-s2pl": make_lock_runner("rw", True),
    "rw-2pl": make_lock_runner("rw", False),
    "glock": make_lock_runner("glock", True),
}


def _pick_runner(framework: str, cfg: EigenConfig) -> Callable:
    """The per-framework executor, with the §12 commute-restricted variant
    substituted when the hotkey workload runs with ``commute=True``."""
    if (cfg.workload == "hotkey" and cfg.commute
            and framework == "optsva-cf"):
        return run_optsva_commute
    return FRAMEWORKS[framework]


# --------------------------------------------------------------------------- #
# Harness                                                                      #
# --------------------------------------------------------------------------- #
#: frameworks whose concurrency control runs over the TCP transport —
#: OptSVA-CF is the paper's system; the baselines poke at in-process state
#: (``holder.obj``) and stay in-proc.
TCP_FRAMEWORKS = ("optsva-cf",)

#: trace events pulled from TCP node-server processes (``trace_dump``) —
#: their rings die with the subprocess, so run_benchmark drains them
#: before teardown and ``--trace-out`` merges them at export time.
_TRACE_EXTRA: List[dict] = []


def _build_inproc(cfg: EigenConfig):
    """In-process topology: Registry nodes with simulated network delay."""
    RefCell.op_time = cfg.op_time_ms / 1e3
    hot_cls = HotCell if cfg.workload == "hotkey" else RefCell
    reg = Registry()
    nodes = [reg.add_node(f"n{i}", network_delay=cfg.network_delay_ms / 1e3)
             for i in range(cfg.nodes)]
    n_clients = cfg.nodes * cfg.clients_per_node
    hot: List = []
    mild_by_client: Dict[int, List] = {}
    for ni, node in enumerate(nodes):
        for i in range(cfg.arrays_per_node):
            hot.append(node.bind(f"hot-{ni}-{i}", hot_cls()))
    for ci in range(n_clients):
        node = nodes[ci % cfg.nodes]
        mild_by_client[ci] = [
            node.bind(f"mild-{ci}-{i}", RefCell())
            for i in range(cfg.arrays_per_node)]
    return reg, hot, mild_by_client, lambda: reg.shutdown()


def _build_tcp(cfg: EigenConfig):
    """Real-wire topology: one server subprocess per node, honest latency.

    Cells are shipped once at bind time and live on their home node; the
    per-operation service time burns *there* (CF delegation), and
    ``network_delay_ms`` is ignored — the wire is real.
    """
    import sys
    from pathlib import Path

    from repro.net.spawn import spawn_cluster

    # Replies ride reader-thread wakeups on the mux connections; the
    # default 5 ms GIL switch interval turns each wakeup into multi-ms
    # convoy latency once many client threads run. (The node servers set
    # the same interval for themselves in repro.net.server.main.)
    sys.setswitchinterval(0.001)
    repo_root = str(Path(__file__).resolve().parents[1])
    # Use the canonical module's RefCell: when this file runs as __main__
    # (python benchmarks/eigenbench.py or python -m benchmarks.eigenbench),
    # the locally defined class would pickle as __main__.RefCell, which the
    # server process cannot import. Direct script invocation also lacks the
    # repo root on sys.path — add it so the package import resolves.
    if repo_root not in sys.path:
        sys.path.insert(0, repo_root)
    from benchmarks.eigenbench import HotCell, RefCell
    Cell = HotCell if cfg.workload == "hotkey" else RefCell
    handles = spawn_cluster(cfg.nodes, extra_paths=[repo_root])
    reg = Registry()
    remote_nodes = [reg.connect(h.address) for h in handles]
    op_time = cfg.op_time_ms / 1e3
    n_clients = cfg.nodes * cfg.clients_per_node
    hot: List = []
    mild_by_client: Dict[int, List] = {}
    for ni, rn in enumerate(remote_nodes):
        for i in range(cfg.arrays_per_node):
            hot.append(rn.bind(f"hot-{ni}-{i}", Cell(0, op_time or None)))
    for ci in range(n_clients):
        rn = remote_nodes[ci % cfg.nodes]
        mild_by_client[ci] = [
            rn.bind(f"mild-{ci}-{i}", RefCell(0, op_time or None))
            for i in range(cfg.arrays_per_node)]

    def teardown() -> None:
        reg.shutdown()
        for h in handles:
            h.stop()

    return reg, hot, mild_by_client, teardown


def _plan_rng(cfg: EigenConfig, framework: str, ci: int) -> random.Random:
    """Per-client plan RNG, seeded with a *stable* string key: str seeding
    hashes via sha512, so plans are identical across processes and hosts
    (``PYTHONHASHSEED``-independent) — required for the exact message-plan
    CI gate over the sim transport."""
    return random.Random(f"eigen:{cfg.seed}:{framework}:{ci}")


def _build_sim(cfg: EigenConfig):
    """Deterministic simulation topology: every node is a
    :class:`~repro.net.simnet.SimNode` inside this process under the
    seeded virtual-time scheduler; every client is a simulated process."""
    from repro.net.simnet import build_simnet

    net = build_simnet(cfg.seed, cfg.nodes)
    op_time = cfg.op_time_ms / 1e3
    setup = net.client_registry("setup")
    remote_nodes = sorted(setup.nodes, key=lambda n: n.name)
    n_clients = cfg.nodes * cfg.clients_per_node
    hot: List = []
    mild_by_client: Dict[int, List] = {}
    addrs = [rn.address for rn in remote_nodes]

    def _followers(ni: int) -> List[str]:
        # Replica chain (DESIGN.md §8): one follower, next node round-robin
        # — the bench measures the replication message plan the sweep
        # proves correct. Single-node topologies have nowhere to replicate.
        return [addrs[(ni + 1) % cfg.nodes]] if cfg.nodes > 1 else []

    hot_cls = HotCell if cfg.workload == "hotkey" else RefCell
    for ni, rn in enumerate(remote_nodes):
        for i in range(cfg.arrays_per_node):
            hot.append(rn.bind(f"hot-{ni}-{i}",
                               hot_cls(0, op_time or None),
                               followers=_followers(ni)))
    for ci in range(n_clients):
        ni = ci % cfg.nodes
        rn = remote_nodes[ni]
        mild_by_client[ci] = [
            rn.bind(f"mild-{ci}-{i}", RefCell(0, op_time or None),
                    followers=_followers(ni))
            for i in range(cfg.arrays_per_node)]
    return net, setup, hot, mild_by_client


def _run_benchmark_sim(framework: str, cfg: EigenConfig) -> Result:
    """The ``sim`` transport harness: clients are simnet actors, the wall
    clock is virtual, and the per-txn message plan (``rpcs_per_txn``,
    ``oneways_per_txn``) is exactly reproducible for a given (cfg, seed) —
    the deterministic primary signal of the CI bench gate."""
    net, setup, hot, mild_by_client = _build_sim(cfg)
    n_clients = cfg.nodes * cfg.clients_per_node
    runner = _pick_runner(framework, cfg)
    stats_per_client = [dict(commits=0, aborts=0, retries=0, ops=0, waits=0)
                        for _ in range(n_clients)]

    plans: List[List[List[Step]]] = []
    for ci in range(n_clients):
        rng = _plan_rng(cfg, framework, ci)
        if cfg.workload == "bank":
            hist: List[Any] = []
            plans.append([_gen_bank_plan(rng, cfg, hot, mild_by_client[ci],
                                         hist)
                          for _ in range(cfg.txns_per_client)])
        elif cfg.workload == "hotkey":
            plans.append([_gen_hotkey_plan(rng, cfg, hot)
                          for _ in range(cfg.txns_per_client)])
        else:
            plans.append([_gen_plan(rng, cfg, hot, mild_by_client[ci])
                          for _ in range(cfg.txns_per_client)])

    def client(ci: int) -> None:
        # Each client is its own simulated *process*: a private registry
        # over its own per-node transports (like one OS process on TCP).
        reg = net.client_registry(f"c{ci}")
        by_name = {}
        st = stats_per_client[ci]
        for steps in plans[ci]:
            local = [(by_name.setdefault(o.name, reg.locate(o.name)), op, v)
                     for o, op, v in steps]
            runner(reg, local, st)
            st["ops"] += len(steps)

    for ci in range(n_clients):
        net.spawn(lambda c=ci: client(c), f"c{ci}")
    t0 = time.monotonic()
    net.run()
    wall = time.monotonic() - t0
    virtual = net.now()
    n_rpc = n_oneway = 0
    for (cid, _node), t in net._transports.items():
        if cid.startswith("c"):
            n_rpc += t.n_rpc
            n_oneway += t.n_oneway
    # server->follower replication one-ways (DESIGN.md §8): counted at the
    # nodes, not the clients — the replication cost of the commit path.
    n_repl = sum(node.replication.n_sent for node in net._nodes.values())
    # §10 membership metrics: lease handoffs completed and renewal
    # one-ways sent, node-side (crashed nodes keep their counters).
    n_migr = sum(node.n_migrations for node in net._nodes.values())
    n_renew = sum(node.leases.n_renews for node in net._nodes.values())
    # §11 durability metrics: ledger records appended and group-commit
    # flush batches, node-side. Exact under simnet (the VirtualDisk is
    # part of the deterministic schedule), so gate-able like the message
    # plan: a protocol change that writes more WAL records per commit —
    # or breaks fsync batching — moves these.
    n_walapp = sum(node.wal.n_appends for node in net._nodes.values()
                   if node.wal is not None)
    n_walsync = sum(node.wal.n_syncs for node in net._nodes.values()
                    if node.wal is not None)
    # §12 commute metrics: one-way delta messages received and deltas
    # folded under the per-class merge lock, node-side. Exact under
    # simnet, gate-able like the rest of the message plan.
    n_cmw = sum(node.n_commute_oneways for node in net._nodes.values())
    n_merged = sum(node.n_merged_deltas for node in net._nodes.values())
    net.shutdown()

    commits = sum(s["commits"] for s in stats_per_client)
    aborts = sum(s["aborts"] for s in stats_per_client)
    retries = sum(s["retries"] for s in stats_per_client)
    ops = sum(s["ops"] for s in stats_per_client)
    waits = sum(s["waits"] for s in stats_per_client)
    attempted = commits + aborts + retries
    return Result(framework=framework,
                  throughput_ops=ops / max(virtual, 1e-9),
                  aborts=aborts, retries=retries, commits=commits,
                  abort_rate_pct=100.0 * (aborts + retries) / max(attempted, 1),
                  wall_s=wall, waits=waits,
                  rpcs_per_txn=round(n_rpc / max(commits, 1), 2),
                  oneways_per_txn=round(n_oneway / max(commits, 1), 2),
                  replication_oneways_per_txn=round(
                      n_repl / max(commits, 1), 2),
                  migrations_per_txn=round(n_migr / max(commits, 1), 3),
                  lease_renews_per_txn=round(n_renew / max(commits, 1), 3),
                  wal_appends_per_txn=round(n_walapp / max(commits, 1), 2),
                  fsync_batches_per_txn=round(n_walsync / max(commits, 1), 2),
                  commute_oneways_per_txn=round(n_cmw / max(commits, 1), 2),
                  merged_deltas_per_txn=round(n_merged / max(commits, 1), 2))


def run_benchmark(framework: str, cfg: EigenConfig,
                  transport: str = "inproc") -> Result:
    if transport in ("tcp", "sim") and framework not in TCP_FRAMEWORKS:
        raise ValueError(
            f"framework {framework!r} does not run over {transport} "
            f"(supported: {', '.join(TCP_FRAMEWORKS)})")
    if transport == "sim":
        return _run_benchmark_sim(framework, cfg)
    build = _build_tcp if transport == "tcp" else _build_inproc
    reg, hot, mild_by_client, teardown = build(cfg)
    n_clients = cfg.nodes * cfg.clients_per_node

    if transport == "tcp":
        # Topology setup (bind/list_bindings) is not part of the per-txn
        # message plan: zero the wire counters before the clients start.
        for node in reg.nodes:
            c = getattr(node, "client", None)
            if c is not None:
                c.n_rpc = c.n_oneway = c.n_inline = c.n_handoff = 0

    runner = _pick_runner(framework, cfg)
    stats_per_client = [dict(commits=0, aborts=0, retries=0, ops=0, waits=0)
                        for _ in range(n_clients)]
    # generate all plans up front (a-priori access sets)
    plans: List[List[List[Step]]] = []
    for ci in range(n_clients):
        rng = _plan_rng(cfg, framework, ci)
        if cfg.workload == "bank":
            hist: List[Any] = []    # locality window spans the client's txns
            plans.append([_gen_bank_plan(rng, cfg, hot, mild_by_client[ci],
                                         hist)
                          for _ in range(cfg.txns_per_client)])
        elif cfg.workload == "hotkey":
            plans.append([_gen_hotkey_plan(rng, cfg, hot)
                          for _ in range(cfg.txns_per_client)])
        else:
            plans.append([_gen_plan(rng, cfg, hot, mild_by_client[ci])
                          for _ in range(cfg.txns_per_client)])

    barrier = threading.Barrier(n_clients + 1)

    def client(ci: int) -> None:
        barrier.wait()
        st = stats_per_client[ci]
        for steps in plans[ci]:
            runner(reg, steps, st)
            st["ops"] += len(steps)

    threads = [threading.Thread(target=client, args=(ci,))
               for ci in range(n_clients)]
    for th in threads:
        th.start()
    barrier.wait()
    t0 = time.monotonic()
    for th in threads:
        th.join()
    wall = time.monotonic() - t0
    n_rpc = n_oneway = n_handoff = 0
    if transport == "tcp":
        # Per-txn wire metrics: sum the NodeClient counters of every
        # connected remote node before teardown closes them.
        for node in reg.nodes:
            c = getattr(node, "client", None)
            if c is not None:
                n_rpc += c.n_rpc
                n_oneway += c.n_oneway
                n_handoff += c.n_handoff
        from repro.obs import txtrace
        if txtrace.enabled:
            # Server-side rings live in the node subprocesses: pull them
            # now — teardown kills the processes. Issued only under
            # --trace-out, never on the gated bench hot path.
            for node in reg.nodes:
                c = getattr(node, "client", None)
                if c is not None:
                    try:
                        _TRACE_EXTRA.extend(c.call("trace_dump"))
                    except Exception:  # noqa: BLE001 - trace is best-effort
                        pass
    teardown()

    commits = sum(s["commits"] for s in stats_per_client)
    aborts = sum(s["aborts"] for s in stats_per_client)
    retries = sum(s["retries"] for s in stats_per_client)
    ops = sum(s["ops"] for s in stats_per_client)
    waits = sum(s["waits"] for s in stats_per_client)
    attempted = commits + aborts + retries
    return Result(framework=framework,
                  throughput_ops=ops / wall,
                  aborts=aborts, retries=retries, commits=commits,
                  abort_rate_pct=100.0 * (aborts + retries) / max(attempted, 1),
                  wall_s=wall, waits=waits,
                  rpcs_per_txn=round(n_rpc / max(commits, 1), 2),
                  oneways_per_txn=round(n_oneway / max(commits, 1), 2),
                  handoffs_per_txn=round(n_handoff / max(commits, 1), 2))


def sweep(frameworks: Sequence[str], cfg: EigenConfig, vary: str,
          values: Sequence[Any], transport: str = "inproc") -> List[Result]:
    out = []
    for v in values:
        c = EigenConfig(**{**cfg.__dict__, vary: v})
        for fw in frameworks:
            r = run_benchmark(fw, c, transport=transport)
            out.append((v, r))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--frameworks", default="all")
    ap.add_argument("--scenario", default="9:1",
                    help="read:write ratio, e.g. 9:1, 5:5, 1:9")
    ap.add_argument("--transport", default="inproc",
                    choices=["inproc", "tcp", "sim"],
                    help="inproc: simulated nodes in one process; tcp: one "
                         "real server subprocess per node, honest wire; "
                         "sim: deterministic virtual-time simulation "
                         "(seeded scheduler, exact message-plan metrics)")
    ap.add_argument("--seed", type=int, default=42,
                    help="schedule seed (plans + the sim scheduler)")
    ap.add_argument("--sweep", default="none",
                    choices=["none", "clients", "nodes", "nodes-mild"])
    ap.add_argument("--workload", default="mix",
                    choices=["mix", "bank", "hotkey"],
                    help="mix: classic ratio plans; bank: long-chain "
                         "transfers (read-modify-write per account — the "
                         "operation-fusion hot path); hotkey: Zipfian "
                         "hot-key increments (the §12 commute workload)")
    ap.add_argument("--no-commute", action="store_true",
                    help="hotkey workload: run the identical plan through "
                         "exact version-gated accesses (the pre-§12 plan) "
                         "instead of commute-restricted delta merging")
    ap.add_argument("--chain-len", type=int, default=4,
                    help="accounts per bank-transfer chain")
    ap.add_argument("--clients-per-node", type=int, default=4)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--txns", type=int, default=5)
    ap.add_argument("--op-ms", type=float, default=0.3)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale parameters (slow)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a merged Perfetto/Chrome trace of the run "
                         "to PATH (load at ui.perfetto.dev). Under "
                         "--transport sim the trace is byte-identical per "
                         "seed; under tcp node-server rings are pulled "
                         "over the wire before teardown.")
    args = ap.parse_args()

    if args.trace_out:
        # Before any server spawns: subprocesses inherit the env flag.
        os.environ["REPRO_TRACE"] = "1"
        from repro.obs import txtrace
        txtrace.enable()

    r, w = (int(x) for x in args.scenario.split(":"))
    read_pct = r / (r + w)
    if args.frameworks == "all":
        fws = list(TCP_FRAMEWORKS if args.transport in ("tcp", "sim")
                   else FRAMEWORKS)
    else:
        fws = args.frameworks.split(",")
    cfg = EigenConfig(nodes=args.nodes,
                      clients_per_node=args.clients_per_node,
                      txns_per_client=args.txns,
                      read_pct=read_pct,
                      op_time_ms=args.op_ms, seed=args.seed,
                      workload=args.workload, chain_len=args.chain_len,
                      commute=not args.no_commute)
    if args.full:
        cfg = EigenConfig(nodes=16, clients_per_node=16, txns_per_client=10,
                          read_pct=read_pct, op_time_ms=3.0, seed=args.seed,
                          workload=args.workload, chain_len=args.chain_len,
                          commute=not args.no_commute)

    print("framework,value,throughput_ops_s,abort_rate_pct,commits,aborts,"
          "retries,waits,rpcs_per_txn,handoffs_per_txn")
    if args.sweep == "none":
        for fw in fws:
            res = run_benchmark(fw, cfg, transport=args.transport)
            print(f"{fw},-,{res.throughput_ops:.1f},{res.abort_rate_pct:.1f},"
                  f"{res.commits},{res.aborts},{res.retries},{res.waits},"
                  f"{res.rpcs_per_txn},{res.handoffs_per_txn}")
    else:
        if args.sweep == "clients":
            pairs = sweep(fws, cfg, "clients_per_node", [2, 4, 8, 16],
                          transport=args.transport)
        elif args.sweep == "nodes":
            pairs = sweep(fws, cfg, "nodes", [2, 4, 8],
                          transport=args.transport)
        else:
            cfg = EigenConfig(**{**cfg.__dict__, "mild_ops": cfg.hot_ops})
            pairs = sweep(fws, cfg, "nodes", [2, 4, 8],
                          transport=args.transport)
        for v, res in pairs:
            print(f"{res.framework},{v},{res.throughput_ops:.1f},"
                  f"{res.abort_rate_pct:.1f},{res.commits},{res.aborts},"
                  f"{res.retries},{res.waits},{res.rpcs_per_txn},"
                  f"{res.handoffs_per_txn}")

    if args.trace_out:
        from repro.obs import export
        n = export.write_trace(args.trace_out, extra_events=_TRACE_EXTRA)
        print(f"# trace: {n} events -> {args.trace_out}", file=sys.stderr)


if __name__ == "__main__":
    main()
