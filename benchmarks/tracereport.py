"""Per-phase latency decomposition of a merged transaction trace.

Reads a Perfetto/Chrome trace written by ``eigenbench --trace-out`` (or
``repro.obs.export.write_trace``) and decomposes each transaction's
client-observed window into disjoint phases:

* **dispense**   — server-side 2PL batched version dispensing (§2.10.2);
* **gate-wait**  — blocked on the access condition ``pv-1 <= lv``;
* **version-wait** — blocked on the commit condition ``pv-1 <= ltv``;
* **service**    — method execution against live state / buffer tasks;
* **commit**     — commit-protocol server work net of waits and service;
* **server-other** — remaining server-side time (marshalling, bookkeeping);
* **wire**       — client RPC time not covered by any server span;
* **client-local** — the rest of the window (plan exec, local buffers).

The phases are computed as nested interval-set subtractions of the client
``txn`` span, so they **sum to the window exactly** by construction (the
report prints the residual, which is 0 up to float rounding — well inside
the 1% acceptance bound). ``vwait`` spans carry no transaction id (the
version gate knows only the private version); they are attributed by
interval containment inside the transaction's own server op spans, which
is exact under the simulation transport's serial execution.

Usage::

    python benchmarks/tracereport.py trace.json [--top 10]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Tuple

Iv = Tuple[float, float]          # half-open interval [start, end), in us

#: server op spans that belong to the commit protocol (DESIGN.md §8)
_COMMIT_OPS = frozenset({
    "commit_wave1", "commit_solo", "commit_chain", "commit_decide",
    "commit_decision", "finish_batch", "wait_termination_batch",
})


def _union(ivs: List[Iv]) -> List[Iv]:
    """Normalize to a sorted disjoint union."""
    out: List[Iv] = []
    for s, e in sorted(ivs):
        if e <= s:
            continue
        if out and s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def _clip(ivs: List[Iv], w: Iv) -> List[Iv]:
    s0, e0 = w
    return _union([(max(s, s0), min(e, e0)) for s, e in ivs
                   if min(e, e0) > max(s, s0)])


def _subtract(a: List[Iv], b: List[Iv]) -> List[Iv]:
    """a \\ b, both disjoint unions."""
    out: List[Iv] = []
    for s, e in a:
        cur = s
        for bs, be in b:
            if be <= cur or bs >= e:
                continue
            if bs > cur:
                out.append((cur, bs))
            cur = max(cur, be)
            if cur >= e:
                break
        if cur < e:
            out.append((cur, e))
    return out


def _total(ivs: List[Iv]) -> float:
    return sum(e - s for s, e in ivs)


def load_spans(path: str) -> Tuple[Dict[int, str], List[dict]]:
    with open(path) as f:
        doc = json.load(f)
    sites: Dict[int, str] = {}
    spans: List[dict] = []
    for e in doc["traceEvents"]:
        if e["ph"] == "M" and e.get("name") == "process_name":
            sites[e["pid"]] = e["args"]["name"]
        elif e["ph"] == "X":
            spans.append(e)
    for e in spans:
        e["site"] = sites.get(e["pid"], f"pid{e['pid']}")
    return sites, spans


def _phases_for(txn: str, spans: List[dict]) -> Dict[str, float]:
    mine = [e for e in spans if e["args"].get("txn") == txn]
    win = [e for e in mine if e["name"] == "txn"
           and e["site"].startswith("client")]
    if not win:
        return {}
    w: Iv = (win[0]["ts"], win[0]["ts"] + win[0]["dur"])
    iv = lambda e: (float(e["ts"]), float(e["ts"] + e["dur"]))  # noqa: E731
    node = lambda e: not e["site"].startswith("client")         # noqa: E731

    rpc = _clip([iv(e) for e in mine if e["name"] == "rpc"], w)
    ops = [e for e in mine if node(e) and e["args"].get("detail") == "op"]
    ops_iv = _clip([iv(e) for e in ops], w)
    svc = _clip([iv(e) for e in mine
                 if e["name"] in ("service", "ro_buffer", "lw_apply")], w)

    # vwait spans carry pv, not txn: attribute by containment in this
    # transaction's own server op spans (exact under sim's serial exec).
    def contained(e) -> bool:
        s, t = iv(e)
        return any(os_ <= s and t <= oe for os_, oe in ops_iv)

    vw_all = [e for e in spans if e["name"] == "vwait" and node(e)]
    gate = _clip([iv(e) for e in vw_all
                  if e["args"].get("detail", "").startswith("access")
                  and contained(e)], w)
    term = _clip([iv(e) for e in vw_all
                  if e["args"].get("detail", "").startswith("termination")
                  and contained(e)], w)

    server = _union(ops_iv + svc)
    dispense = [iv(e) for e in ops if e["name"] == "dispense_batch"]
    commit = [iv(e) for e in ops if e["name"] in _COMMIT_OPS]

    # Nested subtraction: each phase removes what earlier phases claimed,
    # so the eight buckets partition the window exactly.
    out: Dict[str, float] = {"total": _total([w])}
    claimed: List[Iv] = []

    def phase(name: str, ivs: List[Iv]) -> None:
        nonlocal claimed
        part = _subtract(_clip(_union(ivs), w), claimed)
        out[name] = _total(part)
        claimed = _union(claimed + part)

    phase("gate_wait", gate)
    phase("version_wait", term)
    phase("service", svc)
    phase("dispense", dispense)
    phase("commit", commit)
    phase("server_other", server)
    phase("wire", rpc)
    out["client_local"] = _total(_subtract([w], claimed))
    return out


def report(path: str, top: int = 0) -> Dict[str, float]:
    _sites, spans = load_spans(path)
    txns = sorted({e["args"].get("txn") for e in spans
                   if e["name"] == "txn" and e["args"].get("txn")},
                  key=lambda t: int(t[1:]) if t[1:].isdigit() else 0)
    keys = ["dispense", "gate_wait", "version_wait", "service", "commit",
            "server_other", "wire", "client_local", "total"]
    agg = {k: 0.0 for k in keys}
    rows = []
    for t in txns:
        ph = _phases_for(t, spans)
        if not ph:
            continue
        for k in keys:
            agg[k] += ph[k]
        rows.append((t, ph))

    hdr = "txn        " + "".join(f"{k:>14}" for k in keys)
    print(hdr)
    print("-" * len(hdr))
    shown = rows if top <= 0 else sorted(
        rows, key=lambda r: -r[1]["total"])[:top]
    for t, ph in shown:
        print(f"{t:<11}" + "".join(f"{ph[k]:>14.1f}" for k in keys))
    print("-" * len(hdr))
    print(f"{'SUM (us)':<11}" + "".join(f"{agg[k]:>14.1f}" for k in keys))
    parts = sum(agg[k] for k in keys if k != "total")
    resid = abs(parts - agg["total"]) / max(agg["total"], 1e-9)
    print(f"# phases sum to {parts:.1f} of total {agg['total']:.1f} "
          f"(residual {100 * resid:.4f}%)")
    agg["residual_pct"] = 100 * resid
    return agg


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="merged trace JSON (eigenbench --trace-out)")
    ap.add_argument("--top", type=int, default=0,
                    help="show only the N slowest transactions (0 = all)")
    args = ap.parse_args()
    report(args.trace, args.top)


if __name__ == "__main__":
    main()
