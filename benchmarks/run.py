"""Benchmark harness: one function per paper table/figure + roofline tables.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract.
``us_per_call`` is wall-clock microseconds per transaction (Eigenbench
tables) or per step (step bench); ``derived`` carries the figure's metric
(throughput, abort rate, roofline term...).

Scaled-down parameters by default (CI-sized; ~minutes); ``--full`` runs
paper-scale Eigenbench (16 "nodes" x 16 clients, 3 ms ops — slow).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

ROWS = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


# --------------------------------------------------------------------------- #
# Fig. 10: throughput vs client count (3 read:write ratios)                    #
# --------------------------------------------------------------------------- #
def table_fig10_throughput_vs_clients(full: bool = False) -> None:
    import benchmarks.eigenbench as eb
    frameworks = ["optsva-cf", "sva", "tfa", "rw-2pl", "rw-s2pl",
                  "mutex-2pl", "mutex-s2pl", "glock"]
    clients = [4, 8, 16] if not full else [4, 16, 32, 64]
    for ratio, read_pct in (("9:1", 0.9), ("5:5", 0.5), ("1:9", 0.1)):
        for cpn in clients:
            cfg = eb.EigenConfig(
                nodes=4, clients_per_node=cpn, arrays_per_node=10,
                txns_per_client=3, hot_ops=10, read_pct=read_pct,
                op_time_ms=3.0 if full else 0.5)
            for fw in frameworks:
                r = eb.run_benchmark(fw, cfg)
                n_txn = r.commits
                us = 1e6 * r.wall_s / max(n_txn, 1)
                emit(f"fig10/{ratio}/clients={4*cpn}/{fw}", us,
                     f"throughput={r.throughput_ops:.0f}ops/s;"
                     f"abort_rate={r.abort_rate_pct:.1f}%")


# --------------------------------------------------------------------------- #
# Fig. 11: throughput vs node count (hot-array accesses)                       #
# --------------------------------------------------------------------------- #
def table_fig11_throughput_vs_nodes(full: bool = False) -> None:
    import benchmarks.eigenbench as eb
    frameworks = ["optsva-cf", "sva", "tfa", "rw-2pl", "glock"]
    for ratio, read_pct in (("9:1", 0.9), ("5:5", 0.5), ("1:9", 0.1)):
        for nodes in ([2, 4, 8] if not full else [4, 8, 16]):
            cfg = eb.EigenConfig(
                nodes=nodes, clients_per_node=4, arrays_per_node=5,
                txns_per_client=3, hot_ops=10, read_pct=read_pct,
                op_time_ms=3.0 if full else 0.5)
            for fw in frameworks:
                r = eb.run_benchmark(fw, cfg)
                us = 1e6 * r.wall_s / max(r.commits, 1)
                emit(f"fig11/{ratio}/nodes={nodes}/{fw}", us,
                     f"throughput={r.throughput_ops:.0f}ops/s")


# --------------------------------------------------------------------------- #
# Fig. 12: + mild-array accesses (lower contention)                            #
# --------------------------------------------------------------------------- #
def table_fig12_with_mild_arrays(full: bool = False) -> None:
    import benchmarks.eigenbench as eb
    frameworks = ["optsva-cf", "sva", "tfa", "rw-2pl"]
    for ratio, read_pct in (("9:1", 0.9), ("5:5", 0.5), ("1:9", 0.1)):
        cfg = eb.EigenConfig(
            nodes=4, clients_per_node=4, arrays_per_node=10,
            txns_per_client=3, hot_ops=10, mild_ops=10, read_pct=read_pct,
            op_time_ms=3.0 if full else 0.5)
        for fw in frameworks:
            r = eb.run_benchmark(fw, cfg)
            us = 1e6 * r.wall_s / max(r.commits, 1)
            emit(f"fig12/{ratio}/{fw}", us,
                 f"throughput={r.throughput_ops:.0f}ops/s")


# --------------------------------------------------------------------------- #
# Fig. 13: abort rates                                                         #
# --------------------------------------------------------------------------- #
def table_fig13_abort_rates(full: bool = False) -> None:
    import benchmarks.eigenbench as eb
    for cpn in ([4, 8, 16] if not full else [4, 16, 48]):
        for fw in ("optsva-cf", "sva", "tfa"):
            cfg = eb.EigenConfig(
                nodes=4, clients_per_node=cpn, arrays_per_node=10,
                txns_per_client=3, hot_ops=10, read_pct=0.5,
                op_time_ms=0.3)
            r = eb.run_benchmark(fw, cfg)
            us = 1e6 * r.wall_s / max(r.commits, 1)
            emit(f"fig13/clients={4*cpn}/{fw}", us,
                 f"abort_rate={r.abort_rate_pct:.1f}%")


# --------------------------------------------------------------------------- #
# Scheduler-overhead microbench (PR1): op_time=0 isolates framework cost       #
# --------------------------------------------------------------------------- #
def bench_scheduler_overhead(full: bool = False,
                             out: str = "BENCH_PR1.json") -> None:
    """Per-transaction framework overhead with zero-cost operations.

    Two regimes: *contended* (all clients on a small hot array, write-heavy —
    long version chains, many gated executor tasks) and *uncontended*
    (per-client mild arrays only — no blocking at all). ``optsva-cf`` is
    additionally run against the seed poll-and-scan executor replica
    (``benchmarks.seed_executor``) so the scheduling-core win is measured
    in-repo; results land in ``BENCH_PR1.json``.
    """
    import benchmarks.eigenbench as eb
    import benchmarks.seed_executor as seed
    from benchmarks.report import write_bench_json

    txns = 12 if full else 8
    repeats = 7 if full else 5            # thread-scheduling noise: use medians
    configs = {
        "contended": eb.EigenConfig(
            nodes=2, clients_per_node=8, arrays_per_node=4,
            txns_per_client=txns, hot_ops=10, read_pct=0.1,
            op_time_ms=0.0),
        "uncontended": eb.EigenConfig(
            nodes=2, clients_per_node=8, arrays_per_node=8,
            txns_per_client=txns, hot_ops=0, mild_ops=10, read_pct=0.5,
            op_time_ms=0.0),
    }
    frameworks = ["optsva-cf", "sva", "rw-2pl"]

    def median_us(fw, cfg):
        # Return the median run itself so us_per_call and the derived
        # stats (throughput/waits/aborts) come from the same run.
        runs = [eb.run_benchmark(fw, cfg) for _ in range(repeats)]
        runs.sort(key=lambda r: r.wall_s / max(r.commits, 1))
        r = runs[len(runs) // 2]
        return 1e6 * r.wall_s / max(r.commits, 1), r

    json_rows = []
    for cname, cfg in configs.items():
        for fw in frameworks:
            us, r = median_us(fw, cfg)
            derived = (f"throughput={r.throughput_ops:.0f}ops/s;"
                       f"waits={r.waits};aborts={r.aborts}")
            row = {"name": f"sched/{cname}/{fw}", "us_per_call": round(us, 1),
                   "derived": derived, "commits": r.commits, "waits": r.waits}
            if fw == "optsva-cf":
                with seed.patched():
                    seed_us, _ = median_us(fw, cfg)
                gain = 100.0 * (1.0 - us / seed_us) if seed_us else 0.0
                derived += (f";seed_us={seed_us:.1f};"
                            f"improvement={gain:.1f}%")
                row.update(seed_us_per_call=round(seed_us, 1),
                           improvement_pct=round(gain, 1), derived=derived)
            emit(row["name"], us, derived)
            json_rows.append(row)
    write_bench_json(out, json_rows, meta={
        "bench": "scheduler_overhead", "pr": 1, "op_time_ms": 0.0,
        "txns_per_client": txns,
        "note": ("seed_us = identical run under the seed poll-and-scan "
                 "executor replica (benchmarks.seed_executor)")})


# --------------------------------------------------------------------------- #
# Transport-overhead bench (PR2, re-measured per PR): in-proc vs real TCP wire #
# --------------------------------------------------------------------------- #
def bench_transport_overhead(full: bool = False,
                             out: str = "BENCH_PR10.json") -> None:
    """Per-transaction cost of the real wire (``repro.net``), honestly.

    The same Eigenbench schedule (read-dominated 9:1 — the paper's
    headline scenario — a 5:5 mixed one, and the long-chain bank-transfer
    workload exercising the operation-fusion path) runs twice: ``inproc``
    (simulated nodes, zero-latency calls) and ``tcp`` (one real server
    subprocess per node, every operation delegated to its home node over
    the multiplexed pipelined connection). The delta is the wire: framing
    + syscalls + the round trips the protocol could not pipeline away.
    Each tcp row also records the per-transaction *message plan* —
    ``rpcs_per_txn`` (client round trips), ``oneways_per_txn``, and
    ``handoffs_per_txn`` (replies that crossed a thread handoff instead
    of being read inline by their caller-leader) — which are
    deterministic per schedule and therefore gate-able even on a noisy
    host. Results land in the PR's ``BENCH_PR<n>.json`` trajectory point;
    ``benchmarks/check_bench_delta.py`` fails CI when the tcp overhead or
    the RPC count regresses against the newest checked-in baseline.
    """
    import benchmarks.eigenbench as eb
    from benchmarks.report import write_bench_json
    from repro.obs import metrics as obs_metrics
    from repro.obs import txtrace

    txns = 6 if full else 4
    repeats = 7 if full else 5          # shared-box scheduling noise: medians
    configs = {
        "9:1": eb.EigenConfig(
            nodes=2, clients_per_node=4, arrays_per_node=4,
            txns_per_client=txns, hot_ops=10, read_pct=0.9,
            op_time_ms=0.0),
        "5:5": eb.EigenConfig(
            nodes=2, clients_per_node=4, arrays_per_node=4,
            txns_per_client=txns, hot_ops=10, read_pct=0.5,
            op_time_ms=0.0),
        "bank": eb.EigenConfig(
            nodes=2, clients_per_node=4, arrays_per_node=4,
            txns_per_client=txns, op_time_ms=0.0,
            workload="bank", chain_len=6),
    }

    def median_us(cfg, transport):
        runs = [eb.run_benchmark("optsva-cf", cfg, transport=transport)
                for _ in range(repeats)]
        runs.sort(key=lambda r: r.wall_s / max(r.commits, 1))
        r = runs[len(runs) // 2]
        return 1e6 * r.wall_s / max(r.commits, 1), r

    json_rows = []
    for cname, cfg in configs.items():
        inproc_us, r_in = median_us(cfg, "inproc")
        tcp_us, r_tcp = median_us(cfg, "tcp")
        # The deterministic message plan under simnet: ONE run (repeats
        # would measure the same thing — the schedule is a pure function
        # of the seed), exact to the message. This is the primary signal
        # of the CI bench-delta gate; the wall-clock rows above are the
        # warn-only secondary (shared-host scheduling noise swings them
        # 2-4x between windows, CHANGES.md PR 3/4). Obs is enabled just
        # for this run: it adds zero protocol messages (the rings are
        # in-process, test_disabled_tracing_changes_no_wire_metrics), and
        # its histograms read the *virtual* clock — so the gate-wait and
        # version-handoff medians below are deterministic per seed too
        # (warn-only gated by check_bench_delta, latency trajectory).
        was_on = txtrace.enabled
        txtrace.reset()
        obs_metrics.reset()
        txtrace.enable()
        try:
            r_sim = eb.run_benchmark("optsva-cf", cfg, transport="sim")
        finally:
            if not was_on:
                txtrace.disable()
        gate_p50 = obs_metrics.merged_percentile("gate_wait_us", 0.5)
        handoff_p50 = obs_metrics.merged_percentile("handoff_us", 0.5)
        txtrace.reset()
        obs_metrics.reset()
        overhead = tcp_us - inproc_us
        factor = tcp_us / inproc_us if inproc_us else 0.0
        for transport, us, r in (("inproc", inproc_us, r_in),
                                 ("tcp", tcp_us, r_tcp)):
            derived = (f"throughput={r.throughput_ops:.0f}ops/s;"
                       f"aborts={r.aborts};waits={r.waits}")
            if transport == "tcp":
                derived += (f";wire_overhead_us={overhead:.1f};"
                            f"slowdown={factor:.2f}x;"
                            f"rpcs_per_txn={r.rpcs_per_txn};"
                            f"handoffs_per_txn={r.handoffs_per_txn}")
            emit(f"transport/{cname}/{transport}", us, derived)
            json_rows.append({
                "name": f"transport/{cname}/{transport}",
                "us_per_call": round(us, 1), "derived": derived,
                "commits": r.commits, "aborts": r.aborts, "waits": r.waits})
        json_rows[-1].update(wire_overhead_us=round(overhead, 1),
                             slowdown=round(factor, 2),
                             rpcs_per_txn=r_tcp.rpcs_per_txn,
                             oneways_per_txn=r_tcp.oneways_per_txn,
                             handoffs_per_txn=r_tcp.handoffs_per_txn)
        sim_derived = (f"rpcs_per_txn={r_sim.rpcs_per_txn};"
                       f"oneways_per_txn={r_sim.oneways_per_txn};"
                       f"replication_oneways_per_txn="
                       f"{r_sim.replication_oneways_per_txn};"
                       f"migrations_per_txn={r_sim.migrations_per_txn};"
                       f"lease_renews_per_txn={r_sim.lease_renews_per_txn};"
                       f"wal_appends_per_txn={r_sim.wal_appends_per_txn};"
                       f"fsync_batches_per_txn="
                       f"{r_sim.fsync_batches_per_txn};"
                       f"commits={r_sim.commits};aborts={r_sim.aborts};"
                       f"waits={r_sim.waits};"
                       f"gate_wait_p50_us={gate_p50};"
                       f"handoff_p50_us={handoff_p50}")
        emit(f"transport/{cname}/sim", 0.0, sim_derived)
        json_rows.append({
            "name": f"transport/{cname}/sim", "transport": "sim",
            "us_per_call": 0.0, "derived": sim_derived,
            "commits": r_sim.commits, "aborts": r_sim.aborts,
            "waits": r_sim.waits, "seed": cfg.seed,
            "rpcs_per_txn": r_sim.rpcs_per_txn,
            "oneways_per_txn": r_sim.oneways_per_txn,
            "replication_oneways_per_txn":
                r_sim.replication_oneways_per_txn,
            "migrations_per_txn": r_sim.migrations_per_txn,
            "lease_renews_per_txn": r_sim.lease_renews_per_txn,
            "wal_appends_per_txn": r_sim.wal_appends_per_txn,
            "fsync_batches_per_txn": r_sim.fsync_batches_per_txn,
            "gate_wait_p50_us": gate_p50,
            "handoff_p50_us": handoff_p50})
    json_rows.extend(_bench_hotkey_rows())
    json_rows.extend(_bench_migration_rows())
    write_bench_json(out, json_rows, meta={
        "bench": "transport_overhead", "pr": 10, "op_time_ms": 0.0,
        "txns_per_client": txns, "repeats": repeats,
        "note": ("tcp = one node-server subprocess per registry node "
                 "(repro.net), honest wire over the multiplexed pipelined "
                 "transport with leader/follower demux + operation fusion; "
                 "inproc = simulated nodes; sim = deterministic virtual-"
                 "time simulation (repro.net.simnet) whose message-plan "
                 "metrics are exact per seed and gated with EXACT equality "
                 "by check_bench_delta. us_per_call is wall-clock per "
                 "committed transaction, median of `repeats` runs. "
                 "rpcs/oneways/handoffs are client-side message counts "
                 "per committed transaction from the median run. "
                 "gate_wait_p50_us / handoff_p50_us are obs-registry "
                 "(repro.obs.metrics) medians from the sim run's virtual "
                 "clock — deterministic per seed, warn-only gated. "
                 "migrations_per_txn / lease_renews_per_txn are §10 "
                 "membership metrics (lease handoffs completed, renewal "
                 "one-ways sent), node-side, sim rows only. The "
                 "transport/migration rows are the Zipfian hot-key "
                 "scenario: affinity-driven auto-migration must move the "
                 "hot object to its dominant accessor and strictly lower "
                 "rpcs_per_txn post-migration. The transport/hotkey-* rows "
                 "are the §12 commute gate: the identical Zipfian hot-key "
                 "increment plan run exact (version-gated, the pre-§12 "
                 "message plan) and commute-restricted (delta merging) — "
                 "commute must strictly lower rpcs_per_txn and gate-wait "
                 "with equal commits and zero aborts; "
                 "commute_oneways_per_txn / merged_deltas_per_txn count "
                 "deltas shipped one-way and deltas folded under the "
                 "per-class merge lock, node-side, exact per seed.")})


def _bench_migration_rows() -> list:
    """Zipfian hot-key migration scenario (§10), sim transport.

    Two nodes; a pool of hot objects all homed on node0; one client whose
    locality affinity is node1 runs transactions that each touch one
    Zipf-picked hot object plus a node1-homed anchor — two dispense RPCs
    per transaction while the hot object lives on node0, one once
    affinity-driven auto-migration hands its lease to node1. The bench
    runs two equal windows and records the exact message plan of each;
    the gate is directional and hard: ≥1 hot object must migrate to the
    dominant accessor's node and the post-window ``rpcs_per_txn`` must be
    strictly lower than the pre-window's.
    """
    import random as _random

    import benchmarks.eigenbench as eb
    from repro.net.simnet import build_simnet

    n_hot, txns = 6, 24
    net = build_simnet(8, 2)
    setup = net.client_registry("setup")
    nodes = sorted(setup.nodes, key=lambda n: n.name)
    addrs = [rn.address for rn in nodes]
    for node in net._nodes.values():
        node.migrate_auto = True
    for i in range(n_hot):
        nodes[0].bind(f"hot-{i}", eb.RefCell(0), followers=[addrs[1]])
    nodes[1].bind("anchor", eb.RefCell(0), followers=[addrs[0]])
    net.set_affinity("c1", addrs[1])

    # Zipf(s=1.5) over the hot pool: the head object draws ~55% of the
    # accesses — enough votes to cross MIGRATE_THRESHOLD with a 2:1 lead
    # inside the first window.
    weights = [1.0 / (i + 1) ** 1.5 for i in range(n_hot)]
    total_w = sum(weights)

    def pick(rng: "_random.Random") -> int:
        x = rng.random() * total_w
        for i, w in enumerate(weights):
            x -= w
            if x <= 0:
                return i
        return n_hot - 1

    stats = [dict(commits=0, aborts=0, retries=0, waits=0) for _ in range(2)]
    rpc_marks: list = []

    def c1_rpcs() -> int:
        # Total round trips, client AND server-to-server: the client's
        # own plan is topology-independent (writes buffer locally, the
        # dispense/commit chains run peer-to-peer), so the locality win
        # of migration shows up in the peer links — the chained dispense
        # hop and the commit wave/decide hops a single-node transaction
        # no longer needs.
        return sum(t.n_rpc for (cid, _n), t in net._transports.items()
                   if cid == "c1" or cid.startswith("peer:"))

    def accessor() -> None:
        from repro.core.api import TransactionError

        reg = net.client_registry("c1")
        hot = [reg.locate(f"hot-{i}") for i in range(n_hot)]
        anchor = reg.locate("anchor")
        rng = _random.Random("migbench:zipf")
        for window in range(2):
            rpc_marks.append(c1_rpcs())
            for _ in range(txns):
                i = pick(rng)
                while True:
                    try:
                        eb.run_optsva(reg, [(hot[i], "write", 1),
                                            (anchor, "write", 1)],
                                      stats[window])
                        break
                    except TransactionError:
                        # A txn caught the drain-barrier mid-handoff: the
                        # redirect already re-pointed the binding (§10) —
                        # the retry dispenses at the new home directly.
                        stats[window]["retries"] += 1
            rpc_marks.append(c1_rpcs())
            if window == 0:
                # Quiet gap: queued affinity handoffs drain off the op
                # path; the second window measures the settled topology.
                net.sleep(0.05)

    net.spawn(accessor, "c1")
    net.run()
    migrated = sum(node.n_migrations for node in net._nodes.values())
    moved = sorted(name for name in (f"hot-{i}" for i in range(n_hot))
                   if net._nodes["node1"].has_binding(name))
    net.shutdown()

    rows = []
    plans = []
    for window, label in enumerate(("pre", "post")):
        st = stats[window]
        n_rpc = rpc_marks[2 * window + 1] - rpc_marks[2 * window]
        per_txn = round(n_rpc / max(st["commits"], 1), 2)
        plans.append(per_txn)
        derived = (f"rpcs_per_txn={per_txn};commits={st['commits']};"
                   f"aborts={st['aborts']};retries={st['retries']};"
                   f"migrations={migrated};moved={'/'.join(moved)}")
        emit(f"transport/migration/{label}", 0.0, derived)
        rows.append({"name": f"transport/migration/{label}",
                     "transport": "sim", "us_per_call": 0.0,
                     "derived": derived, "commits": st["commits"],
                     "aborts": st["aborts"], "rpcs_per_txn": per_txn,
                     "migrations": migrated})
    if migrated < 1 or not moved:
        raise RuntimeError(
            f"migration bench: no hot object migrated (migrations="
            f"{migrated}, moved={moved}) — affinity-driven handoff broken")
    if plans[1] >= plans[0]:
        raise RuntimeError(
            f"migration bench: rpcs_per_txn did not drop after migration "
            f"(pre={plans[0]}, post={plans[1]})")
    return rows


def _bench_hotkey_rows() -> list:
    """Commute-vs-exact hot-key scenario (DESIGN.md §12), sim transport.

    The same Zipfian hot-key increment plan runs twice on the sim
    transport: once *exact* (``commute=False`` — every ``add`` is a
    version-gated remote invocation, the pre-§12 message plan) and once
    *commute-restricted* (``add`` declared as a commuting method class —
    invocations ship as one-way deltas and fold under the per-class merge
    lock at commit). Both runs are deterministic per seed, so every
    metric is recorded for the exact-equality gate; the directional check
    is hard: commute must strictly lower ``rpcs_per_txn`` while keeping
    commits equal and aborts zero, and the exact run must report zero
    commute traffic (proving the default path is untouched).
    """
    import benchmarks.eigenbench as eb
    from repro.obs import metrics as obs_metrics
    from repro.obs import txtrace

    cfg_kw = dict(nodes=2, clients_per_node=4, arrays_per_node=4,
                  txns_per_client=4, hot_ops=10, op_time_ms=0.0,
                  workload="hotkey")
    rows = []
    results = {}
    for label, commute in (("exact", False), ("commute", True)):
        cfg = eb.EigenConfig(commute=commute, **cfg_kw)
        was_on = txtrace.enabled
        txtrace.reset()
        obs_metrics.reset()
        txtrace.enable()
        try:
            r = eb.run_benchmark("optsva-cf", cfg, transport="sim")
        finally:
            if not was_on:
                txtrace.disable()
        gate_p50 = obs_metrics.merged_percentile("gate_wait_us", 0.5)
        txtrace.reset()
        obs_metrics.reset()
        results[label] = (r, gate_p50)
        derived = (f"rpcs_per_txn={r.rpcs_per_txn};"
                   f"oneways_per_txn={r.oneways_per_txn};"
                   f"replication_oneways_per_txn="
                   f"{r.replication_oneways_per_txn};"
                   f"commute_oneways_per_txn={r.commute_oneways_per_txn};"
                   f"merged_deltas_per_txn={r.merged_deltas_per_txn};"
                   f"wal_appends_per_txn={r.wal_appends_per_txn};"
                   f"fsync_batches_per_txn={r.fsync_batches_per_txn};"
                   f"commits={r.commits};aborts={r.aborts};"
                   f"waits={r.waits};gate_wait_p50_us={gate_p50}")
        emit(f"transport/hotkey-{label}/sim", 0.0, derived)
        rows.append({
            "name": f"transport/hotkey-{label}/sim", "transport": "sim",
            "us_per_call": 0.0, "derived": derived,
            "commits": r.commits, "aborts": r.aborts, "waits": r.waits,
            "seed": cfg.seed,
            "rpcs_per_txn": r.rpcs_per_txn,
            "oneways_per_txn": r.oneways_per_txn,
            "replication_oneways_per_txn": r.replication_oneways_per_txn,
            "commute_oneways_per_txn": r.commute_oneways_per_txn,
            "merged_deltas_per_txn": r.merged_deltas_per_txn,
            "wal_appends_per_txn": r.wal_appends_per_txn,
            "fsync_batches_per_txn": r.fsync_batches_per_txn,
            "gate_wait_p50_us": gate_p50})
    r_ex, _ = results["exact"]
    r_cm, _ = results["commute"]
    if r_cm.aborts or r_ex.aborts:
        raise RuntimeError(
            f"hotkey bench: aborts (exact={r_ex.aborts}, "
            f"commute={r_cm.aborts}) — expected a clean pessimistic run")
    if r_cm.commits != r_ex.commits:
        raise RuntimeError(
            f"hotkey bench: commit counts diverge (exact={r_ex.commits}, "
            f"commute={r_cm.commits}) — commute mode lost transactions")
    if r_cm.rpcs_per_txn >= r_ex.rpcs_per_txn:
        raise RuntimeError(
            f"hotkey bench: commute rpcs_per_txn={r_cm.rpcs_per_txn} not "
            f"below exact {r_ex.rpcs_per_txn} — §12 coordination "
            f"avoidance is not avoiding coordination")
    if r_cm.commute_oneways_per_txn <= 0 or r_cm.merged_deltas_per_txn <= 0:
        raise RuntimeError(
            f"hotkey bench: commute run shipped no deltas "
            f"(oneways={r_cm.commute_oneways_per_txn}, "
            f"merged={r_cm.merged_deltas_per_txn}) — the commute path "
            f"silently fell back to exact dispatch")
    if r_ex.commute_oneways_per_txn or r_ex.merged_deltas_per_txn:
        raise RuntimeError(
            f"hotkey bench: exact run reports commute traffic "
            f"(oneways={r_ex.commute_oneways_per_txn}, "
            f"merged={r_ex.merged_deltas_per_txn}) — the default path "
            f"is contaminated")
    return rows


# --------------------------------------------------------------------------- #
# Roofline tables from the dry-run artifacts (deliverable g)                   #
# --------------------------------------------------------------------------- #
def table_roofline() -> None:
    rdir = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    if not rdir.exists():
        emit("roofline/missing", 0.0, "run `python -m repro.launch.dryrun`")
        return
    for f in sorted(rdir.glob("*--single.json")):
        d = json.loads(f.read_text())
        if "skipped" in d or "error" in d:
            continue
        r = d["roofline"]
        emit(f"roofline/{d['arch']}/{d['shape']}",
             1e6 * max(r["compute_s"], r["memory_s"], r["collective_s"]),
             f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f};"
             f"useful={r['useful_ratio']:.3f};"
             f"comp={r['compute_s']:.3f}s;mem={r['memory_s']:.3f}s;"
             f"coll={r['collective_s']:.3f}s")


# --------------------------------------------------------------------------- #
# CPU step microbenchmark (sanity wall-clock numbers)                          #
# --------------------------------------------------------------------------- #
def bench_train_step() -> None:
    import jax
    import jax.numpy as jnp
    from repro.data.pipeline import DataConfig, make_batch
    from repro.models import Backbone, LayerGroup, ModelConfig
    from repro.optim import adamw
    from repro.runtime.steps import (StepSettings, init_train_state,
                                     make_train_step)

    cfg = ModelConfig(name="bench", family="dense", d_model=128, n_heads=4,
                      n_kv_heads=2, d_ff=512, vocab=1024,
                      groups=(LayerGroup(("attn",), 4),))
    bb = Backbone(cfg, compute_dtype=jnp.float32, remat=False)
    settings = StepSettings(zero3=False, gather_weights=False, remat=False)
    state = init_train_state(bb, jax.random.PRNGKey(0), settings)
    step = jax.jit(make_train_step(bb, adamw.AdamWConfig(), settings),
                   donate_argnums=(0,))
    dcfg = DataConfig(vocab=1024, seq_len=128, global_batch=4)
    batch = make_batch(dcfg, 0)
    state, m = step(state, batch)          # compile
    jax.block_until_ready(m["loss"])
    t0 = time.monotonic()
    n = 10
    for i in range(1, n + 1):
        state, m = step(state, make_batch(dcfg, i))
    jax.block_until_ready(m["loss"])
    us = (time.monotonic() - t0) / n * 1e6
    emit("bench/train_step_cpu_9M", us, f"loss={float(m['loss']):.3f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--tables", default="all",
                    help="comma list: sched,transport,fig10,fig11,fig12,"
                         "fig13,roofline,step")
    ap.add_argument("--bench-out", default="BENCH_PR1.json",
                    help="JSON trajectory point for the sched table")
    ap.add_argument("--transport-out", default="BENCH_PR10.json",
                    help="JSON trajectory point for the transport table "
                         "(per-PR: pass BENCH_PR<n>.json for PR n)")
    args = ap.parse_args()
    tables = (["sched", "transport", "fig10", "fig11", "fig12", "fig13",
               "roofline", "step"]
              if args.tables == "all" else args.tables.split(","))
    print("name,us_per_call,derived")
    if "sched" in tables:
        bench_scheduler_overhead(args.full, out=args.bench_out)
    if "transport" in tables:
        bench_transport_overhead(args.full, out=args.transport_out)
    if "fig10" in tables:
        table_fig10_throughput_vs_clients(args.full)
    if "fig11" in tables:
        table_fig11_throughput_vs_nodes(args.full)
    if "fig12" in tables:
        table_fig12_with_mild_arrays(args.full)
    if "fig13" in tables:
        table_fig13_abort_rates(args.full)
    if "roofline" in tables:
        table_roofline()
    if "step" in tables:
        bench_train_step()


if __name__ == "__main__":
    main()
