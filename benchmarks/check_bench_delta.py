"""Bench-delta gate: fail CI when the transport message plan regresses.

Compares a freshly measured transport-overhead JSON against a checked-in
baseline — by default the **newest** checked-in ``BENCH_PR<n>.json`` that
carries gate-able rows (highest ``<n>``), so the gate tightens
automatically as each PR lands its trajectory point.

Primary signal (hard gate, **exact**): the *simnet* rows. Under the
deterministic simulation transport (``repro.net.simnet``) the per-seed
message plan — ``rpcs_per_txn``, ``oneways_per_txn`` — and the
commit/abort counts are pure functions of the code, so ANY difference
from the baseline is a real protocol change: the gate demands equality,
not a tolerance band. (A deliberate protocol change just re-records the
baseline in the PR that makes it.)

Secondary signals:

* tcp ``rpcs_per_txn`` — hard-gated with ``--max-regress`` tolerance
  (deterministic per schedule, but plans differ from sim's);
* tcp ``wire_overhead_us`` — **warn-only**: shared-host scheduling noise
  swings wall clock 2-4x between windows (CHANGES.md PR 3/4), so it is
  reported for the trajectory but never fails the gate;
* sim ``gate_wait_p50_us`` / ``handoff_p50_us`` — **warn-only**: the
  obs-registry medians of access-gate wait and version-handoff latency
  under the virtual clock (deterministic per seed, but HDR-quantized and
  legitimately moved by protocol changes — a latency trajectory, not a
  correctness gate);
* any abort on a gated row fails — the transport must stay semantically
  clean while getting faster.

Missing rows in the fresh file are an error; extra rows (e.g. a scenario
the baseline predates) are ignored.

Usage::

    python -m benchmarks.check_bench_delta --fresh fresh.json
    python -m benchmarks.check_bench_delta --baseline BENCH_PR5.json \
        --fresh fresh.json --max-regress 0.20
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path
from typing import Dict, Optional


def _tcp_rows(doc: dict) -> Dict[str, dict]:
    return {r["name"]: r for r in doc.get("rows", ())
            if "wire_overhead_us" in r}


def _sim_rows(doc: dict) -> Dict[str, dict]:
    return {r["name"]: r for r in doc.get("rows", ())
            if r.get("transport") == "sim"}


def find_baseline(directory: str, exclude: Optional[str] = None) -> str:
    """Newest checked-in ``BENCH_PR<n>.json`` (highest n) with gate-able
    (tcp or sim) rows."""
    best_n, best = -1, None
    exclude_path = Path(exclude).resolve() if exclude else None
    for f in Path(directory).glob("BENCH_PR*.json"):
        m = re.fullmatch(r"BENCH_PR(\d+)\.json", f.name)
        if not m:
            continue
        if exclude_path is not None and f.resolve() == exclude_path:
            continue
        try:
            doc = json.loads(f.read_text())
        except (OSError, ValueError):
            continue
        n = int(m.group(1))
        if (_tcp_rows(doc) or _sim_rows(doc)) and n > best_n:
            best_n, best = n, f
    if best is None:
        raise SystemExit(
            f"no BENCH_PR<n>.json with gate-able rows found under "
            f"{directory!r}")
    return str(best)


def check(baseline: dict, fresh: dict, max_regress: float) -> int:
    failures = []
    warnings = []

    def gate(name: str, metric: str, base_v: float, new_v: float,
             warn_only: bool = False) -> None:
        limit = base_v * (1.0 + max_regress)
        delta = 100.0 * (new_v - base_v) / base_v if base_v else 0.0
        bad = new_v > limit
        verdict = ("OK" if not bad
                   else "WARN (not gated)" if warn_only else "REGRESSION")
        print(f"{name}: {metric} baseline={base_v:.2f} fresh={new_v:.2f} "
              f"({delta:+.1f}%, limit +{100 * max_regress:.0f}%) {verdict}")
        if bad:
            msg = (f"{name}: {metric} {new_v:.2f} exceeds {limit:.2f} "
                   f"(baseline {base_v:.2f} +{100 * max_regress:.0f}%)")
            (warnings if warn_only else failures).append(msg)

    # -- primary: simnet message plan, EXACT ---------------------------------
    base_sim = _sim_rows(baseline)
    fresh_sim = _sim_rows(fresh)
    for name, base in sorted(base_sim.items()):
        row = fresh_sim.get(name)
        if row is None:
            failures.append(f"{name}: missing from fresh results")
            continue
        if row.get("aborts"):
            failures.append(f"{name}: {row['aborts']} aborts (expected 0)")
        for metric in ("rpcs_per_txn", "oneways_per_txn",
                       "replication_oneways_per_txn", "commits",
                       "migrations_per_txn", "lease_renews_per_txn",
                       "wal_appends_per_txn", "fsync_batches_per_txn",
                       "migrations", "commute_oneways_per_txn",
                       "merged_deltas_per_txn"):
            if metric not in base:
                continue
            b, f_ = base[metric], row.get(metric)
            verdict = "OK" if f_ == b else "REGRESSION (exact gate)"
            print(f"{name}: {metric} baseline={b} fresh={f_} [sim/exact] "
                  f"{verdict}")
            if f_ != b:
                failures.append(
                    f"{name}: deterministic {metric} changed {b} -> {f_} "
                    f"(sim message plans are exact; a deliberate protocol "
                    f"change must re-record the baseline)")
        # Virtual-clock latency medians (repro.obs.metrics, PR 7):
        # deterministic per seed but quantized by the HDR buckets and
        # legitimately moved by protocol changes — warn-only trajectory
        # signal, never a hard gate.
        for metric in ("gate_wait_p50_us", "handoff_p50_us"):
            if metric in base and metric in row:
                gate(name, metric, float(base[metric]),
                     float(row[metric]), warn_only=True)
    if base_sim and not fresh_sim:
        failures.append("baseline has sim rows but fresh run produced none")

    # -- secondary: tcp ------------------------------------------------------
    base_rows = _tcp_rows(baseline)
    fresh_rows = _tcp_rows(fresh)
    for name, base in sorted(base_rows.items()):
        row = fresh_rows.get(name)
        if row is None:
            failures.append(f"{name}: missing from fresh results")
            continue
        if row.get("aborts"):
            failures.append(f"{name}: {row['aborts']} aborts (expected 0)")
        # wall clock: warn-only secondary (shared-host noise)
        gate(name, "wire_overhead_us", float(base["wire_overhead_us"]),
             float(row["wire_overhead_us"]), warn_only=True)
        if "rpcs_per_txn" in base and "rpcs_per_txn" in row:
            gate(name, "rpcs_per_txn", float(base["rpcs_per_txn"]),
                 float(row["rpcs_per_txn"]))
    if not base_rows and not base_sim:
        print("delta-check: baseline has no gate-able rows — nothing to do")
        return 0

    if warnings:
        print("\nbench-delta warnings (wall-clock, not gated):")
        for w in warnings:
            print(f"  ~ {w}")
    if failures:
        print("\nbench-delta gate FAILED:")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    print("\nbench-delta gate passed")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*",
                    help="FRESH results file (positional shorthand for "
                         "--fresh; the old BASELINE FRESH pair is gone — "
                         "baselines auto-select, or pass --baseline)")
    ap.add_argument("--baseline", default=None,
                    help="checked-in BENCH_PR<n>.json (default: the "
                         "newest one with gate-able rows under "
                         "--baseline-dir)")
    ap.add_argument("--fresh", default=None,
                    help="freshly measured transport bench JSON")
    ap.add_argument("--baseline-dir", default=".",
                    help="where checked-in BENCH_PR*.json live")
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="allowed relative increase per tolerance-gated "
                         "metric (sim rows are always exact)")
    args = ap.parse_args()
    baseline_path, fresh_path = args.baseline, args.fresh
    if args.paths:
        if len(args.paths) == 1 and not fresh_path:
            fresh_path = args.paths[0]
        else:
            ap.error("pass one FRESH file (or --fresh); the legacy "
                     "positional BASELINE FRESH form was removed — "
                     "baselines auto-select, or use --baseline")
    if fresh_path is None:
        ap.error("a fresh results file is required")
    if baseline_path is None:
        baseline_path = find_baseline(args.baseline_dir, exclude=fresh_path)
        print(f"delta-check: auto-selected baseline {baseline_path}")
    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    sys.exit(check(baseline, fresh, args.max_regress))


if __name__ == "__main__":
    main()
