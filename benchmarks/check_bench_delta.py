"""Bench-delta gate: fail CI when the TCP wire overhead regresses.

Compares a freshly measured transport-overhead JSON against the checked-in
baseline (the PR's ``BENCH_PR<n>.json``): for every tcp row present in
both, the fresh ``wire_overhead_us`` must not exceed the baseline's by
more than ``--max-regress`` (relative). Missing rows in the fresh file are
an error; extra rows are ignored. Any abort on a tcp row fails the gate —
the transport must stay semantically clean while getting faster.

Usage::

    python -m benchmarks.check_bench_delta BENCH_PR3.json fresh.json \
        --max-regress 0.20
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict


def _tcp_rows(doc: dict) -> Dict[str, dict]:
    return {r["name"]: r for r in doc.get("rows", ())
            if "wire_overhead_us" in r}


def check(baseline: dict, fresh: dict, max_regress: float) -> int:
    base_rows = _tcp_rows(baseline)
    fresh_rows = _tcp_rows(fresh)
    if not base_rows:
        print("delta-check: baseline has no tcp rows — nothing to gate")
        return 0
    failures = []
    for name, base in sorted(base_rows.items()):
        row = fresh_rows.get(name)
        if row is None:
            failures.append(f"{name}: missing from fresh results")
            continue
        if row.get("aborts"):
            failures.append(f"{name}: {row['aborts']} aborts (expected 0)")
        base_us = float(base["wire_overhead_us"])
        new_us = float(row["wire_overhead_us"])
        limit = base_us * (1.0 + max_regress)
        delta = 100.0 * (new_us - base_us) / base_us if base_us else 0.0
        verdict = "OK" if new_us <= limit else "REGRESSION"
        print(f"{name}: baseline={base_us:.1f}us fresh={new_us:.1f}us "
              f"({delta:+.1f}%, limit +{100 * max_regress:.0f}%) {verdict}")
        if new_us > limit:
            failures.append(
                f"{name}: wire_overhead_us {new_us:.1f} exceeds "
                f"{limit:.1f} (baseline {base_us:.1f} +{100 * max_regress:.0f}%)")
    if failures:
        print("\nbench-delta gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nbench-delta gate passed")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="checked-in BENCH_PR<n>.json")
    ap.add_argument("fresh", help="freshly measured transport bench JSON")
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="allowed relative wire_overhead_us increase")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    sys.exit(check(baseline, fresh, args.max_regress))


if __name__ == "__main__":
    main()
