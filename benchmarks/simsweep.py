"""Seed-sweep fuzzer over the deterministic simulation transport (simnet).

Each seed generates a small OptSVA-CF deployment (2-3 nodes, a handful of
client processes running bank-transfer chains, write-only mark ledgers,
and read-only audits; every object bound with a one-follower replica
chain) plus — on most seeds — one crash-stop injection: a §3.4 client
crash at a labeled protocol step, or (``--node-faults``) a home-node
crash at a chosen delivery of a chained-commit / replication op
(DESIGN.md §8). It then runs the whole thing under
:class:`repro.net.simnet.SimNet`'s seeded virtual-time scheduler and
checks the paper's §2-§3.4 invariants plus the §8 robustness ones (zero
partial commits: a crashed client's in-flight commit applies all-or-
nothing; zero lost committed writes: accounts of a crashed home node are
read back through their promoted replica follower):

* **conservation** — transfers are atomic: the global balance sum never
  changes, and each account's final balance equals its initial balance
  plus the net deltas of exactly the *committed* transfers (catches lost
  writes, partial commits, applied-but-unrestored logs);
* **exactly-once marks** — a committed write-only transaction's unique
  tag appears in the ledger exactly once; an aborted or crashed one never
  (§2.8.4 log application, §3.4 "a dead transaction's log is never
  applied");
* **consistent audits** — every *committed* read-only transaction saw a
  consistent snapshot: its sum over all accounts equals the invariant
  total (last-use early release must never expose a torn state to a
  transaction that goes on to commit);
* **pessimism** — fault-free seeds commit everything: zero aborts, zero
  retries (the no-abort guarantee of the pessimistic protocol);
* **convergence** — at quiescence every version header satisfies
  ``gv == lv == ltv``: no leaked/wedged private versions, the §3.4
  rollback-to-oldest + chain-order skip invariant;
* **no lost/double frames** — transport accounting: everything sent was
  delivered exactly once or deliberately dropped by a crash or an active
  partition cut;
* **split-brain freedom (§10)** — no two nodes ever *act as primary* for
  one object in the same lease epoch (the lease layer's auditor hook
  fires on every version grant, bind, promotion, and migration-in);
* **ledger boundedness (§10)** — at quiescence every live node has
  retired every fully-acked decision-ledger entry
  (``fully_acked_unretired() == 0``) and holds at most ``LEDGER_CAP``
  decisions;
* **replayability** — re-running a seed yields a byte-identical schedule
  trace (checked for a sample of seeds per sweep, and for every failing
  seed so the trace it prints is trustworthy).

Membership churn (``--partitions`` / ``--migrations``, DESIGN.md §10):
partition seeds isolate ``node0``'s peer links for the whole run (clients
still reach both sides — the split-brain scenario) with lease TTLs shrunk
so fencing, promise-wait takeover, and epoch-fenced redirects all fire
inside the schedule; migration seeds run a concurrent *migrator* actor
that forces lease handoffs (the ``migrate`` drain-barrier) mid-workload,
turn on affinity-driven auto-migration, and extend the node-crash plan
list with the §10 labels ``node-mid-migration`` (kill the handoff target
before ``migrate_in`` lands — the old primary must keep serving) and
``node-mid-lease-renewal`` (kill a follower as a renewal arrives — the
primary must depart it from the quorum, not fence).

Durability / restarts (``--restarts``, DESIGN.md §11): every crashed
node is restarted under its old identity — a fresh process replays the
seed-deterministic virtual-disk WAL image the crash left behind
(including torn tails) and runs the rejoin protocol against the live
chains — and the plan list grows the ``node-mid-wal-append``,
``restart-mid-catchup``, and ``double-fault-then-restart`` labels. Two
§11 invariants ride on top: no committed-and-WAL'd write is lost across
a restart (the final readback goes through the *healed* chains, and
every restarted node must have replayed a non-empty image), and chain
width recovers — once all nodes are back, each object has exactly one
primary and a live follower again.

Usage::

    python -m benchmarks.simsweep --seeds 200                  # PR gate
    python -m benchmarks.simsweep --seeds 200 --commute        # §12 gate
    python -m benchmarks.simsweep --seeds 100 --node-faults    # failover gate
    python -m benchmarks.simsweep --seeds 100 --node-faults \
        --partitions --migrations          # membership-churn gate (§10)
    python -m benchmarks.simsweep --seeds 100 --node-faults \
        --restarts                         # restart-churn gate (§11)
    python -m benchmarks.simsweep --seeds 5000 --trace-dir sim_traces
    python -m benchmarks.simsweep --seeds 200 --trace-dir sim_traces \
        --trace-failing          # + Perfetto span trace per failing seed
    python -m benchmarks.simsweep --seed 1234 --print-trace    # replay one
"""
from __future__ import annotations

import argparse
import random
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

from repro.core import AbortError, Transaction
from repro.core.api import TransactionError
from repro.net import leases as _leases
from repro.net.demo import HotLedgerAccount, LedgerAccount
from repro.net.replication import LEDGER_CAP
from repro.net.simnet import SimDeadlock, build_simnet

#: The labeled §3.4 crash-stop injection points (the PR-sized sweep must
#: exercise at least 4 distinct ones). Since the chained commit decision
#: (DESIGN.md §8) a multi-domain commit is ONE ``commit_chain`` RPC, so
#: the client-crash points of interest moved: ``pre-commit`` kills the
#: client before it ever asks for a commit (full §3.4 rollback);
#: ``post-commit`` kills it with the request in flight — the coordinator
#: decides and drives steps 2-5 autonomously, so the transfer must apply
#: everywhere or nowhere (the old client-driven step-5 partial-terminate
#: window is CLOSED; the all-or-nothing check below enforces exactly that).
INJECTION_POINTS = [
    ("mid-dispense", "dispense_batch", "after_send"),
    ("mid-open", "open_call", "after_send"),
    ("lw-apply", "lw_apply", "after_send"),
    ("pre-commit", "commit_chain", "before_send"),
    ("post-commit", "commit_chain", "after_send"),
]

#: Node crash-stop plans for ``--node-faults`` (DESIGN.md §8): kill a home
#: node at the nth delivery of a protocol op — the coordinator itself
#: (``commit_chain``), a mid-wave participant (``commit_wave``), a
#: mid-decision-chain participant (``commit_decide``), or a replica
#: follower (``repl_apply`` / ``repl_final``) — plus the timed crash.
#: ``before``/``after`` pick whether the op's message dies with the node
#: or the node dies right after (or parked inside) its handler.
NODE_FAULT_PLANS = [
    ("node-timed", None, None),
    ("node-chain-pre", "commit_chain", "before_deliver"),
    ("node-chain-post", "commit_chain", "after_deliver"),
    ("node-wave-pre", "commit_wave", "before_deliver"),
    ("node-wave-post", "commit_wave", "after_deliver"),
    ("node-decide-pre", "commit_decide", "before_deliver"),
    ("node-decide-post", "commit_decide", "after_deliver"),
    ("node-repl-apply", "repl_apply", "before_deliver"),
    ("node-repl-final", "repl_final", "before_deliver"),
]

#: Extra node crash-stop plans exercised only under ``--migrations``
#: (DESIGN.md §10): crash the *handoff target* as the drain-barrier's
#: ``migrate_in`` arrives (the old primary must keep serving — a torn
#: migration never strands the object), and crash a *follower* as a
#: ``lease_renew`` one-way lands (the primary must mark it departed and
#: shrink the quorum, not fence itself). Appended after the base list so
#: the seed→plan mapping of existing ``--node-faults`` sweeps only
#: changes when the flag is on.
MEMBERSHIP_FAULT_PLANS = [
    ("node-mid-migration", "migrate_in", "before_deliver"),
    ("node-mid-lease-renewal", "lease_renew", "before_deliver"),
]

#: Durability / restart plans exercised only under ``--restarts``
#: (DESIGN.md §11). Appended after the other lists so existing
#: seed→plan mappings only change when the flag is on. These plans are
#: scheduled by virtual time rather than op delivery, and every crashed
#: node is restarted under its old identity (WAL replay + chain rejoin):
#:
#: * ``node-mid-wal-append`` — crash the node AT a WAL frame append,
#:   tearing that frame: replay must truncate the torn tail and the
#:   chain must still heal;
#: * ``restart-mid-catchup`` — crash, restart, then crash AGAIN while
#:   the node is probing/rejoining (anti-entropy catch-up), restart once
#:   more: the second replay sees the partially-caught-up image;
#: * ``double-fault-then-restart`` — crash two nodes (on 2-node seeds:
#:   the whole deployment), then restart both: recovery must
#:   re-establish exactly one primary per object without split-brain.
#:
#: Under ``--restarts`` the base ``NODE_FAULT_PLANS`` crashes also get a
#: restart scheduled, so the final readback exercises healed chains
#: instead of promoted-follower-only service.
RESTART_FAULT_PLANS = [
    ("node-mid-wal-append", None, None),
    ("restart-mid-catchup", None, None),
    ("double-fault-then-restart", None, None),
]
RESTART_LABELS = {label for label, _op, _phase in RESTART_FAULT_PLANS}


def _topology(rng: random.Random) -> Tuple[int, int, int, int]:
    """(nodes, accounts_per_node, clients, txns_per_client) for one seed."""
    return (rng.choice([2, 2, 3]), rng.choice([2, 3]),
            rng.choice([3, 4, 5]), rng.choice([2, 3]))


def run_seed(seed: int, *, faults: bool = True, node_faults: bool = False,
             partitions: bool = False, migrations: bool = False,
             restarts: bool = False, commute: bool = False,
             keep_net: bool = False) -> Dict[str, Any]:
    """Run one seeded schedule; returns the result record (see keys below).

    ``failures`` is the list of violated invariants (empty == seed
    passed); ``trace`` is the byte-replayable schedule.
    """
    rng = random.Random(f"simsweep:{seed}")
    n_nodes, accts_per_node, n_clients, txns_per_client = _topology(rng)
    initial = 1000
    # Membership-churn seeds (§10): a partition seed isolates node0's
    # peer links for the ENTIRE run (cut from t=0 — no in-flight one-way
    # straddles the cut, so every drop is an honest "silence" the lease
    # layer must fence on). Three nodes are forced so the majority side
    # (node1, node2) can host intact chains while node0's objects have
    # their follower across the cut.
    churn_part = partitions and seed % 2 == 1
    if churn_part:
        n_nodes = 3
    # Restart seeds need churn timing too: the §11 rejoin backoff is
    # max(ttl/2, 4*poll), so heal must fit inside the schedule.
    churn = churn_part or migrations or restarts
    # Shrink lease TTLs + reaper poll on churn seeds so renewal rounds,
    # fencing, and promise-wait takeover all fire inside a schedule that
    # lasts only tens of virtual milliseconds.
    net = build_simnet(seed, n_nodes,
                       **({"monitor_poll": 0.002} if churn else {}))
    if churn:
        for node in net._nodes.values():
            node.leases.ttl = 0.01

    setup = net.client_registry("setup")
    nodes = sorted(setup.nodes, key=lambda n: n.name)
    addrs = [rn.address for rn in nodes]
    # Replica chain (DESIGN.md §8): one follower, the next node
    # round-robin — every object survives one node crash. On partition
    # seeds the last node's follower is re-pointed INSIDE the majority
    # group: only the isolated node0's objects have a cross-cut follower
    # (the split-brain scenario under test); a symmetric layout would
    # legitimately lose quorum on both sides.
    follower_of_node = {ni: addrs[(ni + 1) % n_nodes]
                        for ni in range(n_nodes)}
    if churn_part:
        follower_of_node[n_nodes - 1] = addrs[1]
    # Commute sweeps (§12) bind accounts whose ``deposit`` is a declared
    # commuting method class: commute-restricted transfers ship both legs
    # as mergeable deltas, while the exact transfers / marks / audits in
    # the same schedule force snap-backs mid-merge. Everything downstream
    # (conservation, all-or-nothing, audits) is unchanged — the sum must
    # be conserved even when the deltas fold under the merge lock.
    acct_cls = HotLedgerAccount if commute else LedgerAccount
    account_names: List[str] = []
    for ni, rn in enumerate(nodes):
        for ai in range(accts_per_node):
            name = f"acct-{ni}-{ai}"
            rn.bind(name, acct_cls(initial),
                    followers=[follower_of_node[ni]])
            account_names.append(name)
    node_of = {f"acct-{ni}-{ai}": ni for ni in range(n_nodes)
               for ai in range(accts_per_node)}
    total = initial * len(account_names)

    # -- fault plan (deterministic per seed) ---------------------------------
    injected: Optional[str] = None
    node_fault: Optional[str] = None
    partitioned: Optional[str] = None
    restart_targets: List[str] = []
    moves: List[Tuple[str, str]] = []
    if migrations:
        # Forced lease handoffs (§10): a migrator actor drives 1-2
        # ``migrate`` drain-barriers mid-workload; affinity counters +
        # migrate_auto exercise the access-driven path on top.
        for node in net._nodes.values():
            node.migrate_auto = True
        k = rng.choice([1, 2])
        for name in rng.sample(account_names, k):
            moves.append((name,
                          addrs[(node_of[name] + 1) % n_nodes]))
    if churn_part:
        # Partition seeds get no node crash: the cut IS the fault. Cut
        # node0's peer links from t=0 for longer than any schedule runs;
        # clients still reach both sides.
        net.partition(["node0"],
                      [f"node{i}" for i in range(1, n_nodes)],
                      0.0, 120.0)
        partitioned = "partition:node0"
    elif node_faults and seed % 4 != 0:
        plans = (NODE_FAULT_PLANS
                 + (MEMBERSHIP_FAULT_PLANS if migrations else [])
                 + (RESTART_FAULT_PLANS if restarts else []))
        # Under --partitions the crash seeds are exactly seed % 4 == 2
        # (odd seeds partition instead): indexing by the raw seed would
        # stride the plan list by 4, and whenever gcd(4, len(plans)) > 1
        # some plans become unreachable at ANY sweep size — with all
        # churn flags on, len(plans) is 14 and the two odd-indexed
        # restart plans would never fire. Index by the crash-seed
        # ordinal (seed // 4) there so the rotation covers every plan.
        # Elsewhere the raw seed keeps existing seed->plan mappings
        # (and the pinned regression seeds) byte-stable.
        plan_idx = (seed // 4) if (partitions and restarts) else seed
        label, op, phase = plans[plan_idx % len(plans)]
        if label == "node-mid-wal-append":
            # The write itself is the crash point: the nth workload-time
            # WAL append tears and the node dies with it (§11).
            target = f"node{n_nodes - 1}"
            net.inject_wal_crash(target, nth=1 + (seed // len(plans)) % 4,
                                 label=label)
            restart_targets.append(target)
        elif label == "restart-mid-catchup":
            # Crash, restart, crash AGAIN while the rejoin protocol is
            # mid-probe/catch-up, then heal for good via the scheduled
            # restart retries below.
            target = f"node{n_nodes - 1}"
            net.crash_node_at(target, rng.uniform(0.002, 0.008))
            net.restart_node_at(target, 0.02)
            net.crash_node_at(target, 0.02 + rng.uniform(0.0005, 0.004))
            restart_targets.append(target)
        elif label == "double-fault-then-restart":
            # On 2-node seeds this kills the entire deployment: recovery
            # runs from WAL images alone and must re-establish exactly
            # one primary per object.
            a, b = f"node{n_nodes - 1}", f"node{max(n_nodes - 2, 0)}"
            t1, t2 = sorted((rng.uniform(0.002, 0.010),
                             rng.uniform(0.002, 0.010)))
            net.crash_node_at(a, t1)
            net.crash_node_at(b, t2)
            restart_targets += [a, b]
        elif op is None:
            target = f"node{n_nodes - 1}"
            net.crash_node_at(target, rng.uniform(0.001, 0.008))
            if restarts:
                restart_targets.append(target)
        elif op == "migrate_in" and not moves:
            label = None
        else:
            # Coordinator ops land on node0 (first in global domain
            # order); wave/decide hops and replication one-ways land on
            # later nodes — target where the op actually arrives. The
            # §10 ops land where the membership traffic does: migrate_in
            # on the handoff target, lease_renew on a follower.
            if op == "migrate_in":
                target = moves[0][1].split("://", 1)[1]
            elif op == "lease_renew":
                target = "node1"
            else:
                target = "node0" if op == "commit_chain" else "node1"
            nth = 1 + (seed // len(plans)) % 2
            if op in ("migrate_in", "lease_renew"):
                nth = 1
            net.inject_node_crash(target, op, nth=nth, phase=phase,
                                  label=label)
            if restarts:
                restart_targets.append(target)
        node_fault = label
    elif faults and seed % 3 != 0:
        label, op, phase = INJECTION_POINTS[seed % len(INJECTION_POINTS)]
        nth = 1 + (seed // len(INJECTION_POINTS)) % 2
        if op == "lw_apply":
            nth = 1     # c0 runs exactly one write-only transaction
        net.inject_crash("c0", op, nth=nth, phase=phase, label=label)
        injected = label

    pre_restart_nodes = {}
    if restart_targets:
        # Restart every crashed node under its old identity (§11), well
        # after the crash window. restart_node_at is a no-op on a live
        # node, so the later attempts only matter for crashes that fire
        # late in the schedule (delivery-triggered plans) or for the
        # second fault of restart-mid-catchup. The original node objects
        # are kept so the invariants below can tell an actual restart (a
        # fresh SimNode replayed the disk) from a crash that never fired.
        pre_restart_nodes = {t: net._nodes[t]
                             for t in dict.fromkeys(restart_targets)}
        for i, tgt in enumerate(dict.fromkeys(restart_targets)):
            for at in (0.05, 0.2, 0.5):
                net.restart_node_at(tgt, at + 0.002 * i)

    # -- workload ------------------------------------------------------------
    committed_transfers: List[Tuple[List[str], int]] = []
    #: transfers whose commit request may be in flight at a client crash:
    #: the chained commit decides server-side, so such a transfer is
    #: allowed to commit OR roll back — but only atomically (all-or-
    #: nothing check below).
    pending_transfers: List[Tuple[List[str], int]] = []
    committed_marks: List[Tuple[str, str]] = []     # (account, tag)
    attempted_marks: List[Tuple[str, str]] = []
    audit_sums: List[int] = []
    stats = {"commits": 0, "aborts": 0}
    failures: List[str] = []

    def transfer_txn(reg, t_rng) -> None:
        k = t_rng.choice([2, 3])
        chain = t_rng.sample(account_names, min(k, len(account_names)))
        if len({node_of[n] for n in chain}) < 2 and len(nodes) > 1:
            # force a cross-node chain so the multi-domain chained commit
            # is on the table
            other = [n for n in account_names
                     if node_of[n] != node_of[chain[0]]]
            chain[-1] = t_rng.choice(other)
        amt = t_rng.randrange(1, 50)
        t = Transaction(reg)
        proxies = {}
        for i, name in enumerate(chain):
            if commute:
                # HotLedgerAccount's deposit is Mode.WRITE (commute
                # class): declare the legs by mode — withdraw on every
                # account but the last, deposit on every one but the
                # first. These exact accesses snap merging objects back
                # to full OptSVA ordering (§12).
                wr = 1 if i > 0 else 0
                ups = 1 if i < len(chain) - 1 else 0
                proxies[name] = t.accesses(reg.locate(name), 1, wr, ups)
            else:
                ups = 1 if i in (0, len(chain) - 1) else 2
                proxies[name] = t.accesses(reg.locate(name), 1, 0, ups)

        def body(tt):
            for a, b in zip(chain, chain[1:]):
                proxies[a].withdraw(amt)
                proxies[b].deposit(amt)
            return proxies[chain[0]].balance()

        # A SimCrash (BaseException) mid-start leaves the entry pending;
        # every normal outcome (commit or abort) removes it. The list is
        # shared across client threads, so remove THIS entry — a
        # positional pop() can strand another client's entry when a
        # crash interleaves two in-flight transfers.
        entry = (chain, amt)
        pending_transfers.append(entry)
        try:
            t.start(body)
        except Exception:
            pending_transfers.remove(entry)
            raise
        pending_transfers.remove(entry)
        committed_transfers.append(entry)
        stats["commits"] += 1

    def commute_transfer_txn(reg, t_rng) -> None:
        # §12 commute-restricted transfer: both legs are deposits of the
        # same commuting class (one negative, one positive), declared via
        # ``t.commutes`` — they skip version-gated dispensing and ship as
        # mergeable one-way deltas, yet the global sum is conserved and
        # the all-or-nothing rule still binds a crashed client's commit.
        src, dst = t_rng.sample(account_names, 2)
        amt = t_rng.randrange(1, 50)
        t = Transaction(reg)
        ps = t.commutes(reg.locate(src), 1)
        pd = t.commutes(reg.locate(dst), 1)
        entry = ([src, dst], amt)
        pending_transfers.append(entry)
        try:
            t.start(lambda tt: (ps.deposit(-amt), pd.deposit(amt)))
        except Exception:
            pending_transfers.remove(entry)
            raise
        pending_transfers.remove(entry)
        committed_transfers.append(entry)
        stats["commits"] += 1

    def commute_burst_txn(reg, t_rng) -> None:
        # Single-object §12 fast path: the whole access set is one
        # commute-declared access on one node, so dispensing defers
        # entirely and the first DELTA_FLUSH deposits ship as a pipelined
        # ``commute_delta`` one-way (the rest ride the commit). The
        # amounts pair up to net zero, so the conservation invariant is
        # indifferent to whether the burst committed.
        name = t_rng.choice(account_names)
        amts = [t_rng.randrange(1, 50) for _ in range(5)]
        t = Transaction(reg)
        p = t.commutes(reg.locate(name), 2 * len(amts))

        def body(tt):
            for a in amts:
                p.deposit(a)
            for a in amts:
                p.deposit(-a)

        t.start(body)
        stats["commits"] += 1

    def mark_txn(reg, t_rng, cid: str, tag: str) -> None:
        name = t_rng.choice(account_names)
        t = Transaction(reg)
        p = t.writes(reg.locate(name), 1)
        attempted_marks.append((name, tag))
        t.start(lambda tt: p.mark(tag))
        committed_marks.append((name, tag))
        stats["commits"] += 1

    def audit_txn(reg, t_rng) -> None:
        t = Transaction(reg)
        proxies = [t.reads(reg.locate(n), 1) for n in account_names]
        got = t.start(lambda tt: sum(p.balance() for p in proxies))
        audit_sums.append(got)
        stats["commits"] += 1

    def client(cid: str) -> None:
        if partitioned:
            # Start past node0's fence point (see the warm actor below):
            # no commit may ever be acknowledged by the primary that is
            # about to be fenced — its cross-cut replication one-ways are
            # silently dropped, so anything it acknowledged after the cut
            # would be silently lost to the promoted follower (§10 leaves
            # that to heal-time reconciliation, out of scope here).
            net.sleep(0.03)
        reg = net.client_registry(cid)
        c_rng = random.Random(f"simsweep:{seed}:{cid}")
        # c0 (the injection target) runs a fixed mix that contains every
        # injectable op: transfers (dispense/open/finish), then a
        # write-only mark (lw_apply), then an audit. Commute sweeps
        # prepend a commute-restricted transfer — it adds a dispense (so
        # mid-dispense crashes can hit a delta-holding client) but no
        # open_call / lw_apply / commit_chain, keeping every original
        # injection label reachable — and add both commute kinds to the
        # other clients' draw.
        pool = ["transfer", "transfer", "mark", "audit"]
        c0_mix = ["transfer", "transfer", "mark", "audit"]
        if commute:
            pool += ["ctransfer", "cburst"]
            c0_mix = ["ctransfer"] + c0_mix
        kinds = (c0_mix if cid == "c0" else
                 [c_rng.choice(pool) for _ in range(txns_per_client)])
        for i, kind in enumerate(kinds):
            try:
                if kind == "transfer":
                    transfer_txn(reg, c_rng)
                elif kind == "ctransfer":
                    commute_transfer_txn(reg, c_rng)
                elif kind == "cburst":
                    commute_burst_txn(reg, c_rng)
                elif kind == "mark":
                    mark_txn(reg, c_rng, cid, f"{cid}.t{i}")
                else:
                    audit_txn(reg, c_rng)
            except AbortError:
                stats["aborts"] += 1
            except TransactionError:
                # RemoteObjectFailure after a home-node crash-stop: the
                # transaction already rolled back on surviving nodes
                # (§3.4); the client carries on.
                stats["aborts"] += 1

    # Split-brain auditor (§10): the lease layer reports every act-as-
    # primary event; two different nodes acting for one object in the
    # SAME lease epoch is the §10 safety violation.
    acted: Dict[Tuple[str, int], str] = {}
    split_brain: List[str] = []

    def _auditor(name: str, epoch: int, node_name: str) -> None:
        prev = acted.setdefault((name, epoch), node_name)
        if prev != node_name:
            split_brain.append(f"split-brain: {name} epoch {epoch} "
                               f"served by both {prev} and {node_name}")

    _leases.set_split_brain_auditor(_auditor)

    if partitioned:
        def warm() -> None:
            # Fence node0 BEFORE the workload starts. Its renewals cross
            # the cut and can never be acked, so one post-expiry contact
            # re-arms the lease and starts a doomed renewal round (the
            # idle-lapse rule), and every contact after THAT expiry
            # fences. Reads only — nothing mutates the doomed copy.
            reg = net.client_registry("warm")
            net.sleep(0.012)
            try:
                for name in account_names:
                    if node_of[name] == 0:
                        reg.locate(name).raw_call("balance")
            except Exception as e:  # noqa: BLE001 - surfaced as a failure
                failures.append(f"warm reader failed: {e!r}")
        net.spawn(warm, "warm")

    migrated: List[Tuple[str, str, bool]] = []
    if moves:
        for ci in range(n_clients):
            net.set_affinity(f"c{ci}", addrs[ci % n_nodes])

        def migrator() -> None:
            # Forced lease handoffs mid-workload (§10 drain-barrier). A
            # refused handoff (target dead / across the cut) must leave
            # the old primary serving — recorded and checked below.
            reg = net.client_registry("migrator")
            m_rng = random.Random(f"simsweep:{seed}:migrator")
            by_addr = {rn.address: rn for rn in reg.nodes}
            for name, target in moves:
                net.sleep(m_rng.uniform(0.001, 0.004))
                try:
                    ok = by_addr[addrs[node_of[name]]].client.call(
                        "migrate", name=name, target=target)
                except Exception:  # noqa: BLE001 - src dead/cut: refused
                    ok = False
                migrated.append((name, target, bool(ok)))
        net.spawn(migrator, "migrator")

    for ci in range(n_clients):
        net.spawn(lambda cid=f"c{ci}": client(cid), f"c{ci}")

    try:
        net.run()
    except SimDeadlock as e:
        failures.append(f"deadlock: {e.args[0].splitlines()[0]}")

    # -- invariants ----------------------------------------------------------
    # Every account is read back — accounts whose home node crashed are
    # read through their promoted replica follower (DESIGN.md §8), which
    # is itself under test: committed state must survive the home node.
    balances = {}
    marks = {}
    readable = []
    for name in account_names:
        shared = setup.locate(name)
        try:
            balances[name] = shared.raw_call("balance")
            marks[name] = shared.raw_call("read_marks")
            readable.append(name)
        except Exception as e:  # noqa: BLE001 - lost replica = lost writes
            failures.append(f"account {name} unreadable after faults: {e!r}")

    if len(readable) == len(account_names):
        expected = {n: initial for n in account_names}
        for chain, amt in committed_transfers:
            expected[chain[0]] -= amt
            expected[chain[-1]] += amt
        if sum(balances.values()) != total:
            failures.append(
                f"conservation: sum={sum(balances.values())} != {total}")
        # A transfer whose client crashed with the commit request in
        # flight may legally land either way — but atomically: apply its
        # deltas all-or-nothing (zero partial commits, zero lost commits).
        candidates = [expected]
        for chain, amt in pending_transfers:
            nxt = []
            for exp in candidates:
                withp = dict(exp)
                withp[chain[0]] -= amt
                withp[chain[-1]] += amt
                nxt.extend([exp, withp])
            candidates = nxt
        if not any(all(balances[n] == exp[n] for n in account_names)
                   for exp in candidates):
            failures.append(
                f"partial commit: balances={balances} match no all-or-"
                f"nothing assignment of {len(pending_transfers)} pending "
                f"transfer(s) over expected={expected}")
        for got in audit_sums:
            if got != total:
                failures.append(f"committed audit saw torn sum {got} "
                                f"!= {total}")
    committed = set(committed_marks)
    for name in readable:
        seen = marks[name]
        for tag in seen:
            if (name, tag) not in committed:
                failures.append(
                    f"uncommitted mark {tag!r} applied on {name}")
        for (mname, tag) in committed:
            if mname == name and seen.count(tag) != 1:
                failures.append(f"mark {tag!r} applied "
                                f"{seen.count(tag)}x on {name}")
    # §10: a partition or a forced migration legally aborts in-flight
    # transactions (fenced primary, drain-barrier refusals) — only truly
    # fault-free schedules must be abort-free.
    if (injected is None and node_fault is None and partitioned is None
            and not moves and stats["aborts"]):
        failures.append(f"pessimism: {stats['aborts']} aborts in a "
                        f"fault-free schedule")
    # A forced migration can abort the victim client's transaction before
    # its injected op is ever attempted — only migration-free seeds must
    # reach their injection point.
    if injected is not None and not net.fired_injections and not moves:
        failures.append(f"injection {injected!r} never fired")
    bad = net.converged()
    if bad:
        failures.append(f"unconverged headers: {bad}")
    if net.sent != net.delivered + net.dropped:
        failures.append(f"frame accounting: sent={net.sent} != "
                        f"delivered={net.delivered}+dropped={net.dropped}")

    # -- §10 invariants: split-brain freedom + ledger boundedness -----------
    _leases.set_split_brain_auditor(None)
    failures.extend(split_brain)
    for node in net._nodes.values():
        if not node.alive:
            continue
        stuck = node.replication.fully_acked_unretired()
        if stuck:
            failures.append(f"ledger: {node.node_name} holds {stuck} "
                            f"fully-acked unretired decision(s)")
        held = len(node.replication.decisions)
        if held > LEDGER_CAP:
            failures.append(f"ledger: {node.node_name} holds {held} "
                            f"decisions > LEDGER_CAP={LEDGER_CAP}")

    # -- §11 invariants: durability across restart + chain-width heal -------
    # (a) No committed-and-WAL'd write lost: the readback above already
    #     went through the healed chains — an account that is unreadable
    #     or off its all-or-nothing balance set has failed those checks.
    #     Here we pin down that durability was actually exercised: every
    #     restarted node came back, and came back by REPLAYING a
    #     non-empty WAL image (not as a blank node).
    # (b) Chain width recovers after heal: once every node is back, each
    #     object has exactly one primary and its chain has regrown to
    #     the configured one-follower bound.
    if restart_targets:
        for tgt, orig in pre_restart_nodes.items():
            node = net._nodes.get(tgt)
            if node is None or not node.alive:
                failures.append(f"restart: {tgt} never came back "
                                f"({node_fault})")
            elif node is not orig and (node._recovered is None
                                       or not node._recovered.objects):
                # A crash that never fired leaves the ORIGINAL node (and
                # its empty first-boot image) in place — only an actual
                # restart must have replayed a non-empty WAL.
                failures.append(f"restart: {tgt} came back without a "
                                f"WAL image to replay ({node_fault})")
        if all(node.alive for node in net._nodes.values()):
            for name in account_names:
                # A stale binding behind a §10 redirect tombstone is not
                # a primary — every access through it redirects.
                prims = [node for node in net._nodes.values()
                         if node.has_binding(name)
                         and name not in node.leases.moved]
                if len(prims) != 1:
                    failures.append(
                        f"chain heal: {name} bound on "
                        f"{sorted(n.node_name for n in prims)} "
                        f"({node_fault})")
                    continue
                prim = prims[0]
                live_fl = [a for a in prim.replication.followers_of(name)
                           if a not in prim.leases.departed]
                if not live_fl:
                    failures.append(f"chain heal: {name} has no live "
                                    f"follower after restart "
                                    f"({node_fault})")

    out = {
        "seed": seed, "failures": failures, "trace": net.trace_text(),
        "commits": stats["commits"], "aborts": stats["aborts"],
        "pending": list(pending_transfers),
        "committed": list(committed_transfers),
        "balances": balances,
        "injected": net.fired_injections[0] if net.fired_injections
                    else (node_fault or partitioned),
        "nodes": n_nodes, "clients": n_clients,
        "partitioned": partitioned, "migrated": migrated,
        # §12 delta accounting (node-side): deltas received one-way and
        # deltas folded under the merge lock — the sweep-level check
        # demands the commute path was actually exercised, not silently
        # snapped back to exact dispatch everywhere.
        "commute_oneways": sum(n.n_commute_oneways
                               for n in net._nodes.values()),
        "merged_deltas": sum(n.n_merged_deltas
                             for n in net._nodes.values()),
    }
    if keep_net:
        out["net"] = net
    else:
        net.shutdown()
    return out


def _span_trace_failing_seed(seed: int, out: Path, *, faults: bool,
                             node_faults: bool, partitions: bool = False,
                             migrations: bool = False,
                             restarts: bool = False,
                             commute: bool = False) -> None:
    """Replay a failing seed with txtrace enabled and export the merged
    Perfetto span trace next to its schedule trace. The schedule is a
    pure function of the seed, so the replay reproduces the failure and
    the span trace shows *where* each transaction spent its time when it
    went wrong (open it at ui.perfetto.dev)."""
    from repro.obs import export, txtrace

    was_enabled = txtrace.enabled
    txtrace.reset()
    txtrace.enable()
    try:
        run_seed(seed, faults=faults, node_faults=node_faults,
                 partitions=partitions, migrations=migrations,
                 restarts=restarts, commute=commute)
    finally:
        if not was_enabled:
            txtrace.disable()
    n = export.write_trace(str(out))
    txtrace.reset()
    print(f"  span trace ({n} events) -> {out}")


def sweep(seeds: range, *, faults: bool = True, node_faults: bool = False,
          partitions: bool = False, migrations: bool = False,
          restarts: bool = False, commute: bool = False,
          replay_check: int = 10,
          trace_dir: Optional[str] = None,
          trace_failing: bool = False) -> int:
    failed: List[Dict[str, Any]] = []
    coverage: Dict[str, int] = {}
    n_migrated = n_refused = 0
    n_deltas = n_merged = 0
    replayed = 0
    for seed in seeds:
        res = run_seed(seed, faults=faults, node_faults=node_faults,
                       partitions=partitions, migrations=migrations,
                       restarts=restarts, commute=commute)
        if res["injected"]:
            coverage[res["injected"]] = coverage.get(res["injected"], 0) + 1
        for _name, _target, ok in res.get("migrated", ()):
            n_migrated += 1 if ok else 0
            n_refused += 0 if ok else 1
        n_deltas += res["commute_oneways"]
        n_merged += res["merged_deltas"]
        if res["failures"] or replayed < replay_check:
            res2 = run_seed(seed, faults=faults, node_faults=node_faults,
                            partitions=partitions, migrations=migrations,
                            restarts=restarts, commute=commute)
            replayed += 1
            if res2["trace"] != res["trace"]:
                res["failures"].append(
                    "NON-DETERMINISTIC: replay trace diverged")
        if res["failures"]:
            failed.append(res)
            print(f"seed {seed}: FAIL {res['failures']}")
            if trace_dir:
                d = Path(trace_dir)
                d.mkdir(parents=True, exist_ok=True)
                (d / f"seed-{seed}.trace").write_text(res["trace"])
                print(f"  trace -> {d / f'seed-{seed}.trace'}")
                if restarts:
                    # §11 forensics: dump every node's virtual-disk WAL
                    # image so a failing restart seed can be dissected
                    # offline (repro.net.wal.replay reads these bytes)
                    res_w = run_seed(seed, faults=faults,
                                     node_faults=node_faults,
                                     partitions=partitions,
                                     migrations=migrations,
                                     restarts=restarts, commute=commute,
                                     keep_net=True)
                    for nn, disk in res_w["net"]._disks.items():
                        p = d / f"seed-{seed}-{nn}.wal"
                        p.write_bytes(disk.data)
                        print(f"  wal image -> {p}")
                    res_w["net"].shutdown()
                if trace_failing:
                    _span_trace_failing_seed(
                        seed, d / f"seed-{seed}.trace.json",
                        faults=faults, node_faults=node_faults,
                        partitions=partitions, migrations=migrations,
                        restarts=restarts, commute=commute)
            else:
                print("  --- replayable schedule (tail) ---")
                for line in res["trace"].splitlines()[-40:]:
                    print(f"  {line}")
    n = len(list(seeds))
    print(f"\nsimsweep: {n} seeds, {n - len(failed)} passed, "
          f"{len(failed)} failed; replay-checked {replayed}")
    print(f"crash-injection coverage: "
          f"{ {k: coverage[k] for k in sorted(coverage)} }")
    if migrations:
        print(f"forced migrations: {n_migrated} handed off, "
              f"{n_refused} refused (dead/cut target)")
    if commute:
        print(f"commute deltas: {n_deltas} shipped one-way, "
              f"{n_merged} folded under the merge lock")
    rc = 1 if failed else 0
    if commute and n >= 50 and n_merged == 0:
        # Conservation-under-merged-deltas is only meaningful if deltas
        # actually merged: an all-snap-back sweep silently degrades to
        # the exact path and proves nothing about §12.
        print("FAIL: commute sweep folded zero deltas — the commute "
              "path never engaged")
        rc = 1
    if faults and n >= 50:
        distinct = len([k for k in coverage if not k.startswith("node-")])
        if node_faults:
            distinct = len([k for k in coverage if k.startswith("node-")])
            if distinct < 4:
                print(f"FAIL: only {distinct} distinct node-crash plans "
                      f"exercised (need >= 4)")
                rc = 1
        elif distinct < 4:
            print(f"FAIL: only {distinct} distinct §3.4 injection points "
                  f"exercised (need >= 4)")
            rc = 1
        if restarts:
            # Only enforce full restart-label coverage when the sweep
            # had enough crash seeds to walk the whole plan rotation:
            # partitions consume odd seeds and seed % 4 == 0 never
            # crashes, so the plan-drawing seeds are n/4 (partitions)
            # or 3n/4 of the sweep.
            plen = (len(NODE_FAULT_PLANS)
                    + (len(MEMBERSHIP_FAULT_PLANS) if migrations else 0)
                    + len(RESTART_FAULT_PLANS))
            crash_seeds = n // 4 if partitions else (3 * n) // 4
            if crash_seeds >= plen:
                missing = sorted(RESTART_LABELS - set(coverage))
                if missing:
                    print(f"FAIL: restart plans never exercised: "
                          f"{missing}")
                    rc = 1
    return rc


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seeds", type=int, default=200,
                    help="number of seeds to sweep")
    ap.add_argument("--start", type=int, default=0, help="first seed")
    ap.add_argument("--seed", type=int, default=None,
                    help="run exactly one seed (debug/replay)")
    ap.add_argument("--no-faults", action="store_true",
                    help="disable crash injection (pure schedule search)")
    ap.add_argument("--node-faults", action="store_true",
                    help="also crash-stop home nodes on some seeds "
                         "(relaxed invariants on those)")
    ap.add_argument("--partitions", action="store_true",
                    help="isolate node0's peer links on odd seeds (§10 "
                         "split-brain scenario: fencing + takeover)")
    ap.add_argument("--migrations", action="store_true",
                    help="force lease handoffs mid-workload, enable "
                         "affinity auto-migration, and add the §10 "
                         "membership crash plans")
    ap.add_argument("--restarts", action="store_true",
                    help="restart every crashed node under its old "
                         "identity (§11 WAL replay + chain rejoin) and "
                         "add the durability crash plans; implies "
                         "--node-faults")
    ap.add_argument("--commute", action="store_true",
                    help="bind commuting-deposit accounts and mix "
                         "commute-restricted transfers into the workload "
                         "(§12): conservation must hold while deltas "
                         "merge, and the sweep fails if no delta ever "
                         "folds")
    ap.add_argument("--replay-check", type=int, default=10,
                    help="re-run this many seeds and require "
                         "byte-identical traces")
    ap.add_argument("--trace-dir", default=None,
                    help="write failing-seed traces here (CI artifact dir)")
    ap.add_argument("--trace-failing", action="store_true",
                    help="with --trace-dir: replay each failing seed with "
                         "span tracing on and write the merged Perfetto "
                         "trace (seed-<n>.trace.json) beside its schedule")
    ap.add_argument("--print-trace", action="store_true",
                    help="with --seed: print the full schedule trace")
    args = ap.parse_args()

    node_faults = args.node_faults or args.restarts
    if args.seed is not None:
        res = run_seed(args.seed, faults=not args.no_faults,
                       node_faults=node_faults,
                       partitions=args.partitions,
                       migrations=args.migrations,
                       restarts=args.restarts,
                       commute=args.commute)
        if args.print_trace:
            sys.stdout.write(res["trace"])
        print(f"seed {args.seed}: commits={res['commits']} "
              f"aborts={res['aborts']} injected={res['injected']} "
              f"migrated={res['migrated']} failures={res['failures']}")
        sys.exit(1 if res["failures"] else 0)

    sys.exit(sweep(range(args.start, args.start + args.seeds),
                   faults=not args.no_faults,
                   node_faults=node_faults,
                   partitions=args.partitions,
                   migrations=args.migrations,
                   restarts=args.restarts,
                   commute=args.commute,
                   replay_check=args.replay_check,
                   trace_dir=args.trace_dir,
                   trace_failing=args.trace_failing))


if __name__ == "__main__":
    main()
