"""Render EXPERIMENTS.md tables from results/dryrun artifacts, and persist
benchmark runs as BENCH_*.json points of the per-PR perf trajectory.

    PYTHONPATH=src python -m benchmarks.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from pathlib import Path


def write_bench_json(path, rows, meta=None) -> None:
    """Write one BENCH_*.json trajectory point.

    ``rows`` is a list of dicts (at minimum ``name``/``us_per_call``/
    ``derived`` mirroring the CSV contract); ``meta`` carries run context.
    """
    payload = {
        "meta": {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "python": platform.python_version(),
            "machine": platform.machine(),
            **(meta or {}),
        },
        "rows": rows,
    }
    Path(path).parent.mkdir(parents=True, exist_ok=True)
    Path(path).write_text(json.dumps(payload, indent=2) + "\n")
    # status to stderr: stdout carries the name,us_per_call,derived CSV
    print(f"wrote {path} ({len(rows)} rows)", file=sys.stderr, flush=True)


def fmt_cell(d):
    r = d["roofline"]
    m = d["memory"]
    peak = (m["peak_bytes"] or 0) / 2 ** 30
    return (f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {peak:.2f} |")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rdir = Path(args.dir)
    rows, skips, errors = [], [], []
    for f in sorted(rdir.glob("*.json")):
        if "--" not in f.stem or f.stem.count("-") > f.stem.count("--") * 2 + 6:
            pass
        d = json.loads(f.read_text())
        tagged = f.stem.split("--")[-1] not in ("single", "multi")
        if tagged:
            continue
        if "error" in d:
            errors.append(f"{d['arch']}×{d['shape']}×{d['mesh']}: {d['error']}")
            continue
        if "skipped" in d:
            if d["mesh"] == args.mesh:
                skips.append(f"{d['arch']} × {d['shape']}")
            continue
        if d["mesh"] != args.mesh:
            continue
        rows.append((d["arch"], d["shape"], fmt_cell(d)))
    print("| arch | shape | mesh | compute_s | memory_s | collective_s "
          "| dominant | useful | roofline_frac | peak_GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for _, _, line in sorted(rows):
        print(line)
    print()
    print(f"Skipped cells ({len(skips)}): " + "; ".join(skips))
    if errors:
        print("ERRORS:")
        for e in errors:
            print("  ", e)


if __name__ == "__main__":
    main()
