"""Fault-tolerance demo (paper §3.4 + runtime crash/restart).

1. Remote-object failure: crash-stop an object mid-workload; transactions
   touching it get RemoteObjectFailure and compensate; others are unharmed.
2. Transaction (client) failure: a client "crashes" holding an object; the
   TransactionMonitor times it out, the object rolls itself back and
   self-releases, and a successor proceeds.
3. Trainer crash/restart: inject a crash mid-training, restart the process
   state from the atomic checkpoint, and verify losses continue exactly
   (the stateless pipeline regenerates the same batches).

    PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import threading
import time

import jax
import jax.numpy as jnp

from repro.core import TransactionMonitor
from repro.dtm import (AbortError, Mode, Registry, RemoteObjectFailure,
                       Transaction, access, bind)


class Counter:
    def __init__(self):
        self.n = 0

    @access(Mode.READ)
    def get(self):
        return self.n

    @access(Mode.UPDATE)
    def incr(self):
        self.n += 1
        return self.n


def demo_object_failure() -> None:
    print("=== 1. remote object failure (crash-stop) ===")
    reg = Registry()
    node = reg.add_node("n1")
    ok = bind(node, "ok", Counter())
    doomed = bind(node, "doomed", Counter())

    doomed.fail()   # crash-stop

    t = Transaction(reg)
    p_ok = t.updates(ok, 1)
    p_doomed = t.updates(doomed, 1)
    try:
        t.start(lambda _t: (p_ok.incr(), p_doomed.incr()))
    except RemoteObjectFailure as e:
        print("  caught:", e, "-> programmer compensates / re-plans")
    # a transaction on healthy objects is unaffected
    t2 = Transaction(reg)
    p2 = t2.updates(ok, 1)
    t2.start(lambda _t: p2.incr())
    print("  healthy object value:", ok.holder.obj.n)
    reg.shutdown()


def demo_client_crash() -> None:
    print("=== 2. client crash -> object self-rollback (§3.4) ===")
    reg = Registry()
    node = reg.add_node("n1")
    shared = bind(node, "x", Counter())
    monitor = TransactionMonitor(reg, timeout=0.5, poll_interval=0.05)
    monitor.start()

    def crashing_client():
        t = Transaction(reg)
        p = t.updates(shared, 2)
        def body(t):
            p.incr()          # modifies, holds the object
            time.sleep(10)    # "crash": never completes
        t.start(body)

    th = threading.Thread(target=crashing_client, daemon=True)
    th.start()
    time.sleep(0.2)
    print("  value while held by crashed client:", shared.holder.obj.n)

    # successor blocked on the access condition until the monitor rolls back
    t0 = time.monotonic()
    t = Transaction(reg, wait_timeout=5.0)
    p = t.updates(shared, 1)
    t.start(lambda _t: p.incr())
    print(f"  successor proceeded after {time.monotonic()-t0:.2f}s; "
          f"value={shared.holder.obj.n} (crashed txn's +1 rolled back)")
    print("  monitor rollbacks:", monitor.rollbacks)
    monitor.stop()
    reg.shutdown()


def demo_crash_restart() -> None:
    print("=== 3. trainer crash + checkpoint restart ===")
    import shutil
    from repro.data.pipeline import DataConfig
    from repro.models import Backbone, LayerGroup, ModelConfig
    from repro.optim import adamw
    from repro.runtime.steps import StepSettings
    from repro.runtime.train_loop import Trainer, TrainerConfig

    shutil.rmtree("/tmp/repro_ft_demo", ignore_errors=True)
    cfg = ModelConfig(name="ft-demo", family="dense", d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab=512,
                      groups=(LayerGroup(("attn",), 2),))
    bb = Backbone(cfg, compute_dtype=jnp.float32, remat=False)
    args = dict(
        opt_cfg=adamw.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30),
        data_cfg=DataConfig(vocab=512, seq_len=32, global_batch=4),
        tcfg=TrainerConfig(total_steps=30, ckpt_every=10,
                           ckpt_dir="/tmp/repro_ft_demo", log_every=10),
        settings=StepSettings(zero3=False, gather_weights=False, remat=False),
    )
    tr = Trainer(bb, **args)
    try:
        state = tr.init_or_restore()
        tr.run(state, crash_at=17)
    except RuntimeError as e:
        print("  crash injected:", e)
    finally:
        tr.shutdown()

    tr2 = Trainer(bb, **args)
    try:
        state = tr2.init_or_restore()     # resumes from step-10 checkpoint
        tr2.run(state)
        print(f"  resumed at step {tr2.start_step}, finished at step 30; "
              f"final loss {tr2.metrics_log[-1]['loss']:.4f}")
    finally:
        tr2.shutdown()


if __name__ == "__main__":
    demo_object_failure()
    demo_client_crash()
    demo_crash_restart()
