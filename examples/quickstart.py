"""Quickstart: OptSVA-CF transactions over shared objects (paper Figs. 7-9).

Runs the paper's bank-account example — two accounts on two "hosts", a
transfer transaction with a manual-abort guard — then demonstrates the
paper's headline behaviors: early release parallelism, buffered read-only
access, and abort-free execution under contention.

Here the "hosts" are in-process accounting entities. For the same example
run over a *real* wire — node-server subprocesses, TCP RPCs, server-side
§3.4 crash rollback — see ``examples/distributed_quickstart.py``
(``repro.net``, DESIGN.md §3.1).

    PYTHONPATH=src python examples/quickstart.py
"""
import threading
import time

from repro.dtm import AbortError, Mode, Registry, Transaction, access, bind


class Account:
    def __init__(self, balance: int = 0):
        self.bal = balance

    @access(Mode.READ)
    def balance(self) -> int:
        return self.bal

    @access(Mode.UPDATE)
    def deposit(self, v: int) -> None:
        self.bal += v

    @access(Mode.UPDATE)
    def withdraw(self, v: int) -> None:
        self.bal -= v

    @access(Mode.WRITE)
    def reset(self) -> None:
        self.bal = 0


def main() -> None:
    reg = Registry()
    server1 = reg.add_node("server-1")
    server2 = reg.add_node("server-2")
    bind(server1, "A", Account(1000))
    bind(server2, "B", Account(500))

    # --- the paper's Fig. 9 transaction ------------------------------------
    t = Transaction(reg)
    a = t.accesses(reg.locate("A"), 1, 0, 1)   # ≤1 read, ≤1 update
    b = t.updates(reg.locate("B"), 1)          # ≤1 update

    def transfer(t):
        a.withdraw(100)
        b.deposit(100)
        if a.balance() < 0:
            t.abort()

    t.start(transfer)
    print("after transfer: A =", reg.locate("A").holder.obj.bal,
          " B =", reg.locate("B").holder.obj.bal)

    # --- manual abort rolls everything back --------------------------------
    t2 = Transaction(reg)
    a2 = t2.accesses(reg.locate("A"), 1, 0, 1)
    b2 = t2.updates(reg.locate("B"), 1)

    def doomed(t):
        a2.withdraw(10_000)     # would overdraw
        b2.deposit(10_000)
        if a2.balance() < 0:
            t.abort()           # -> AbortError, state restored

    try:
        t2.start(doomed)
    except AbortError as e:
        print("aborted as expected:", e)
    print("after abort:    A =", reg.locate("A").holder.obj.bal,
          " B =", reg.locate("B").holder.obj.bal)

    # --- early release: 100 concurrent transfers, zero aborts ---------------
    def worker(i: int) -> None:
        t = Transaction(reg)
        src = t.updates(reg.locate("A" if i % 2 else "B"), 1)
        dst = t.updates(reg.locate("B" if i % 2 else "A"), 1)
        t.start(lambda _t: (src.withdraw(1), dst.deposit(1)))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(100)]
    t0 = time.monotonic()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    total = reg.locate("A").holder.obj.bal + reg.locate("B").holder.obj.bal
    print(f"100 concurrent transfers in {time.monotonic()-t0:.2f}s, "
          f"total conserved: {total} (expected 1500), aborts: 0")
    reg.shutdown()


if __name__ == "__main__":
    main()
