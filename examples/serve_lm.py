"""Serving example: prefill + batched decode with a KV cache.

Demonstrates the serve path that the decode_32k / long_500k dry-run cells
lower: batched prefill over the prompt, then synchronized batched decode
steps with ring-buffer caches for windowed layers. Works for any assigned
arch via --arch (reduced config on CPU).

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b --tokens 32
    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import Backbone, get_config, reduced


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--ctx", type=int, default=128)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    bb = Backbone(cfg, compute_dtype=jnp.float32, remat=False)
    params = bb.init(jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(7)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len),
                                 0, cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.is_enc_dec:
        batch["enc_frames"] = jax.random.normal(
            key, (args.batch, cfg.enc_seq, cfg.d_model))

    prefill = jax.jit(lambda p, b: bb.prefill(p, b, args.ctx))
    decode = jax.jit(bb.decode_step)

    t0 = time.monotonic()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.monotonic() - t0
    print(f"[{cfg.name}] prefill {args.batch}x{args.prompt_len}: "
          f"{t_prefill*1e3:.1f}ms (incl. compile)")

    out_tokens = []
    next_tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)[:, None]
    t0 = time.monotonic()
    for i in range(args.tokens):
        logits, cache = decode(params, cache, next_tok.astype(jnp.int32))
        next_tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)[:, None]
        out_tokens.append(next_tok)
    jax.block_until_ready(out_tokens[-1])
    dt = time.monotonic() - t0
    seq = jnp.concatenate(out_tokens, axis=1)
    print(f"decoded {args.tokens} tokens/seq for {args.batch} seqs "
          f"in {dt*1e3:.1f}ms ({args.tokens*args.batch/dt:.0f} tok/s, "
          f"incl. compile)")
    print("sample continuation (seq 0):", seq[0].tolist())


if __name__ == "__main__":
    main()
