"""Replica chains + follower failover quickstart (DESIGN.md §8).

Spawns two real node-server processes, binds a bank account on the first
with the second configured as its replica follower, commits a transfer,
then SIGKILLs the primary mid-run: the next transaction transparently
promotes the follower and the committed balance survives the home node.

    PYTHONPATH=src python examples/replicated_bank.py
"""
import time

from repro.core import Registry, RemoteObjectFailure, Transaction
from repro.net.demo import Account
from repro.net.spawn import spawn_server


def txn_balance(reg, name):
    t = Transaction(reg)
    p = t.reads(reg.locate(name), 1)
    return t.start(lambda _t: p.balance())


def txn_withdraw(reg, name, amt):
    t = Transaction(reg)
    p = t.updates(reg.locate(name), 1)
    t.start(lambda _t: p.withdraw(amt))


def main() -> None:
    print("=== replicated bank: committed state survives the home node ===")
    with spawn_server("bank-primary") as primary, \
            spawn_server("bank-replica") as replica:
        reg = Registry()
        reg.connect(primary.address)
        reg.connect(replica.address)
        for node in reg.nodes:
            if node.address == primary.address:
                # ordered follower chain: the replica is seeded now and
                # receives every committed write before the commit acks
                node.bind("savings", Account(1000),
                          followers=[replica.address])
        print(f"  bound 'savings' on {primary.name}, "
              f"follower chain -> {replica.name}")

        txn_withdraw(reg, "savings", 100)
        print("  committed withdraw(100); balance =",
              txn_balance(reg, "savings"))

        print(f"  SIGKILL {primary.name} (crash-stop: no shutdown, "
              f"no cleanup)")
        primary.kill()

        # A transaction begun inside the crash-detection window fails
        # with RemoteObjectFailure (§3.4: the programmer retries); the
        # retry fails over — the first live follower is deterministically
        # promoted and serves the COMMITTED state, not the initial one.
        deadline = time.monotonic() + 10.0
        while True:
            try:
                bal = txn_balance(reg, "savings")
                break
            except RemoteObjectFailure:
                if time.monotonic() > deadline:
                    raise
                time.sleep(0.05)
        print("  balance after failover =", bal)
        assert bal == 900, bal

        # the promoted follower is a full primary: commits keep flowing
        txn_withdraw(reg, "savings", 50)
        print("  committed withdraw(50) on the promoted follower; "
              "balance =", txn_balance(reg, "savings"))
        assert txn_balance(reg, "savings") == 850
        reg.shutdown()
    print("  OK: the home node died, the money did not")


if __name__ == "__main__":
    main()
