"""Replica chains, live lease migration, follower failover, and durable
restart (DESIGN.md §8 + §10 + §11).

Spawns two real node-server processes (each with a write-ahead ledger),
binds a bank account on the first with the second configured as its
replica follower, commits a transfer, then migrates the account's
ownership lease to the replica LIVE — the client follows the
epoch-fenced redirect without reconnecting, and the old primary joins
the chain as a follower. The new home is then SIGKILLed mid-run: the
next transaction transparently promotes the follower (the original
primary) and the committed balance survives.

Final act (§11): the SIGKILLed node is respawned under its old
identity — same name, same port, same wal_dir. It replays its ledger,
discovers it was superseded, and rejoins the survivor's chain as tail
follower via anti-entropy catch-up. When the survivor is killed too,
the reborn node is promoted and serves the FULL committed history,
including everything that happened while it was dead.

    PYTHONPATH=src python examples/replicated_bank.py
"""
import tempfile
import time

from repro.dtm import (RemoteObjectFailure, Transaction, bind, connect,
                       spawn_server)
from repro.net.demo import Account


def txn_balance(reg, name):
    t = Transaction(reg)
    p = t.reads(reg.locate(name), 1)
    return t.start(lambda _t: p.balance())


def txn_withdraw(reg, name, amt, retries=1):
    # One retry: a transaction that catches a migration's drain-barrier
    # gets the epoch-fenced redirect (the binding is already re-pointed
    # when it surfaces) — the retry dispenses at the new home directly.
    for attempt in range(retries + 1):
        t = Transaction(reg)
        p = t.updates(reg.locate(name), 1)
        try:
            t.start(lambda _t: p.withdraw(amt))
            return
        except RemoteObjectFailure:
            if attempt == retries:
                raise


def main() -> None:
    print("=== replicated bank: committed state survives the home node ===")
    wal_dir = tempfile.mkdtemp(prefix="bank-wal-")
    with spawn_server("bank-primary", wal_dir=wal_dir) as primary, \
            spawn_server("bank-replica", wal_dir=wal_dir) as replica:
        reg = connect(primary.address, replica.address)
        # ordered follower chain: the replica is seeded now and
        # receives every committed write before the commit acks
        bind(reg.connect(primary.address), "savings", Account(1000),
             followers=[replica.address])
        print(f"  bound 'savings' on {primary.name}, "
              f"follower chain -> {replica.name}")

        txn_withdraw(reg, "savings", 100)
        print("  committed withdraw(100); balance =",
              txn_balance(reg, "savings"))

        # -- live lease migration (DESIGN.md §10) --------------------------
        # Hand the ownership lease to the replica while the client keeps
        # its binding: `migrate` is a drain-barrier (in-flight versions
        # finish, state + epoch+1 ship, the old home leaves an epoch-
        # fenced redirect tombstone) and the old primary joins the new
        # chain as a follower. The client's next transaction follows the
        # redirect without reconnecting.
        for node in reg.nodes:
            if node.address == primary.address:
                assert node.client.call("migrate", name="savings",
                                        target=replica.address)
        print(f"  migrated 'savings' lease {primary.name} -> "
              f"{replica.name} (drain-barrier, epoch-fenced redirect)")
        txn_withdraw(reg, "savings", 25)
        bal = txn_balance(reg, "savings")
        print("  committed withdraw(25) through the redirect; balance =",
              bal)
        assert bal == 875, bal

        # -- crash the NEW home: the chain survived the migration ----------
        print(f"  SIGKILL {replica.name} (crash-stop: no shutdown, "
              f"no cleanup)")
        replica.kill()

        # A transaction begun inside the crash-detection window fails
        # with RemoteObjectFailure (§3.4: the programmer retries); the
        # retry fails over — the first live follower is deterministically
        # promoted and serves the COMMITTED state, not the initial one.
        deadline = time.monotonic() + 10.0
        while True:
            try:
                bal = txn_balance(reg, "savings")
                break
            except RemoteObjectFailure:
                if time.monotonic() > deadline:
                    raise
                time.sleep(0.05)
        print("  balance after failover =", bal)
        assert bal == 875, bal

        # the promoted follower is a full primary: commits keep flowing
        txn_withdraw(reg, "savings", 50)
        print("  committed withdraw(50) on the promoted follower; "
              "balance =", txn_balance(reg, "savings"))
        assert txn_balance(reg, "savings") == 825

        # -- durable restart: the dead node comes BACK (DESIGN.md §11) -----
        # Respawn the SIGKILLed node under its old identity (same name,
        # port, wal_dir). It replays its write-ahead ledger, probes its
        # last known chain, learns it was superseded, and splices back
        # in as tail follower — anti-entropy catch-up replaces its stale
        # image with the survivor's current state.
        port = int(replica.address.rsplit(":", 1)[1])
        print(f"  respawning {replica.name} on port {port} with its old "
              f"WAL (replay + chain rejoin)")
        reborn = spawn_server("bank-replica", port=port, wal_dir=wal_dir)
        try:
            deadline = time.monotonic() + 15.0
            while True:
                info = primary.client.call("list_bindings")
                if reborn.address in info.get("followers",
                                              {}).get("savings", ()):
                    break
                assert time.monotonic() < deadline, \
                    "restarted node never rejoined the chain"
                time.sleep(0.1)
            print(f"  {reborn.name} rejoined the chain as tail follower "
                  f"(caught up while it was dead)")

            # a production client refreshes chain membership from
            # list_bindings; this demo re-points its one proxy by hand
            reg.locate("savings").followers = [reborn.address]

            print(f"  SIGKILL {primary.name} — the reborn node must "
                  f"take over")
            primary.kill()
            deadline = time.monotonic() + 10.0
            while True:
                try:
                    bal = txn_balance(reg, "savings")
                    break
                except RemoteObjectFailure:
                    if time.monotonic() > deadline:
                        raise
                    time.sleep(0.05)
            print("  balance served by the restarted node =", bal)
            assert bal == 825, bal   # includes commits made while it was dead
            reg.shutdown()
        finally:
            reborn.stop()
    print("  OK: the lease moved, both homes died, one came back — "
          "the money never flinched")


if __name__ == "__main__":
    main()
