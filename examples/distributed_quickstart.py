"""Distributed quickstart: OptSVA-CF over a real TCP wire (DESIGN.md §3.1).

The in-process quickstart's bank-transfer example, but *distributed for
real*: two node-server subprocesses each home one account; the transaction
runs in this client process and every operation — the balance read, the
deposit, the withdrawal, checkpointing, rollback on abort — executes on the
account's home node. Only versions and return values cross the wire.

Shows, over actual sockets:

1. the paper's Fig. 9 transfer transaction (commit);
2. a manual abort whose rollback is performed *by the home nodes*;
3. early-release parallelism: concurrent transfers, zero aborts;
4. §3.4 crash-stop: a client process killed mid-transaction has its held
   objects rolled back by the server-side transaction monitor, and a
   survivor transaction then commits.

    PYTHONPATH=src python examples/distributed_quickstart.py
"""
import subprocess
import sys
import textwrap
import threading
import time
from pathlib import Path

from repro.dtm import AbortError, Transaction, bind, connect, spawn_server
from repro.net.demo import Account

SRC = str(Path(__file__).resolve().parents[1] / "src")


def main() -> None:
    # --- two real node processes ------------------------------------------
    with spawn_server("bank-east", monitor_timeout=1.0) as east, \
         spawn_server("bank-west", monitor_timeout=1.0) as west:
        print(f"node processes: {east.name}@{east.address} "
              f"(pid {east.proc.pid}), {west.name}@{west.address} "
              f"(pid {west.proc.pid})")

        reg = connect(east.address, west.address)
        bind(reg.connect(east.address), "A", Account(1000))
        bind(reg.connect(west.address), "B", Account(500))
        A, B = reg.locate("A"), reg.locate("B")

        # --- the paper's Fig. 9 transaction, now across processes ---------
        t = Transaction(reg)
        a = t.accesses(A, 1, 0, 1)   # ≤1 read, ≤1 update
        b = t.updates(B, 1)          # ≤1 update

        def transfer(t):
            a.withdraw(100)
            b.deposit(100)
            if a.balance() < 0:
                t.abort()

        t.start(transfer)
        print("after transfer: A =", A.raw_call("balance"),
              " B =", B.raw_call("balance"))

        # --- manual abort: the home nodes restore their checkpoints -------
        t2 = Transaction(reg)
        a2 = t2.accesses(A, 1, 0, 1)
        b2 = t2.updates(B, 1)

        def doomed(t):
            a2.withdraw(10_000)
            b2.deposit(10_000)
            if a2.balance() < 0:
                t.abort()

        try:
            t2.start(doomed)
        except AbortError as e:
            print("aborted as expected:", e)
        print("after abort:    A =", A.raw_call("balance"),
              " B =", B.raw_call("balance"))

        # --- early release over the wire: concurrent transfers, 0 aborts --
        def worker(i: int) -> None:
            t = Transaction(reg)
            src = t.updates(A if i % 2 else B, 1)
            dst = t.updates(B if i % 2 else A, 1)
            t.start(lambda _t: (src.withdraw(1), dst.deposit(1)))

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(20)]
        t0 = time.monotonic()
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        total = A.raw_call("balance") + B.raw_call("balance")
        print(f"20 concurrent transfers in {time.monotonic()-t0:.2f}s, "
              f"total conserved: {total} (expected 1500), aborts: 0")

        # --- §3.4: crash a client mid-transaction --------------------------
        victim = subprocess.Popen([sys.executable, "-c", textwrap.dedent(f"""
            import os, sys
            sys.path.insert(0, {SRC!r})
            from repro.dtm import Transaction, connect
            reg = connect({east.address!r})
            t = Transaction(reg)
            a = t.accesses(reg.locate("A"), 1, 0, 1)
            t.begin()
            a.withdraw(10_000)        # holds A, modified it...
            print("victim holds A, dying now", flush=True)
            os._exit(1)               # ...and crash-stops: no cleanup
        """)], stdout=subprocess.PIPE, text=True)
        print("victim:", victim.stdout.readline().strip())
        victim.wait()

        # The survivor may catch the cascade: if it buffered A's
        # early-released state before the rollback landed, it is doomed
        # (invalid instance, §2.3) and must re-run — after which it reads
        # the restored balance.
        bal, attempts = None, 0
        while bal is None:
            attempts += 1
            survivor = Transaction(reg, wait_timeout=10.0)
            s = survivor.reads(A, 1)
            try:
                bal = survivor.start(lambda _t: s.balance())
            except AbortError:
                print(f"survivor attempt {attempts}: cascading abort, re-running")
        print(f"survivor read A = {bal} (attempt {attempts}) after the "
              f"server-side §3.4 rollback")
        reg.shutdown()


if __name__ == "__main__":
    main()
