"""End-to-end training driver: a ~100M-parameter qwen3-family model.

Full stack: transactional state store (control plane), AdamW, deterministic
data pipeline, async transactional checkpointing, straggler detection, and
crash/restart — on whatever devices are available (CPU here; the same code
pjit-shards on a pod via --mesh).

    PYTHONPATH=src python examples/train_lm.py --steps 60 --d-model 256
    PYTHONPATH=src python examples/train_lm.py --steps 300 --d-model 768  # ~100M
    PYTHONPATH=src python examples/train_lm.py --resume      # crash restart
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig
from repro.models import Backbone, LayerGroup, ModelConfig
from repro.optim import adamw
from repro.runtime.steps import StepSettings
from repro.runtime.train_loop import Trainer, TrainerConfig


def build_config(args) -> ModelConfig:
    n_heads = args.d_model // 64
    return ModelConfig(
        name="train-lm-demo",
        family="dense",
        d_model=args.d_model,
        n_heads=n_heads,
        n_kv_heads=n_heads // 2 if n_heads % 2 == 0 else 1,
        d_ff=args.d_model * 4,
        vocab=8192,
        groups=(LayerGroup(("attn",), args.layers),),
        qk_norm=True,
        tie_embeddings=True,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="inject a crash at this step (restart with --resume)")
    args = ap.parse_args()

    cfg = build_config(args)
    bb = Backbone(cfg, compute_dtype=jnp.float32, remat=False)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        jax.eval_shape(bb.init, jax.random.PRNGKey(0))))
    print(f"model: {cfg.name} d={cfg.d_model} L={cfg.n_layers} "
          f"params={n_params/1e6:.1f}M")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=20,
                                total_steps=args.steps)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    tcfg = TrainerConfig(total_steps=args.steps,
                         ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, log_every=10)
    trainer = Trainer(bb, opt_cfg, data_cfg, tcfg,
                      StepSettings(zero3=False, gather_weights=False,
                                   remat=False))
    try:
        state = trainer.init_or_restore()
        state = trainer.run(state, crash_at=args.crash_at)
        first = trainer.metrics_log[0]["loss"] if trainer.metrics_log else None
        last = trainer.metrics_log[-1]["loss"] if trainer.metrics_log else None
        print(f"done: loss {first:.3f} -> {last:.3f} over "
              f"{len(trainer.metrics_log)} steps; "
              f"checkpoints at {trainer.async_ckpt.saved}")
        if trainer.straggler.events:
            print(f"straggler events: {trainer.straggler.events}")
    finally:
        trainer.shutdown()


if __name__ == "__main__":
    main()
