from .suprema import StepAccessPlan, release_points, step_suprema
__all__ = ["StepAccessPlan", "release_points", "step_suprema"]
