"""Suprema derivation for training-step transactions (DESIGN.md §2.2).

OptSVA-CF's early release depends on *a-priori knowledge* of access counts
(paper §2.2: suprema from the programmer, a type checker, or static
analysis). For a training step this knowledge is exact and derivable from
the model structure — this module is the "static analyzer" for our domain:

* each layer-block's weights are **read** once in forward, once in backward,
  and once more when rematerialized;
* each block's gradient is **written** once, at a known point in backward
  (→ release the gradient object immediately after: the per-layer
  reduce-scatter schedule);
* the optimizer **updates** each parameter exactly once per step.

``step_suprema`` returns these bounds per parameter group; the transactional
store uses them to declare trainer transactions, and the overlap schedule in
``launch.shardings`` is their data-plane transcription (weight all-gather =
asynchronous read-only buffering; per-layer grad reduce-scatter = early
release on last write).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.api import Suprema
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class StepAccessPlan:
    """Per-parameter-group access bounds for one training step."""

    weight_reads: int          # forward + backward (+ remat)
    grad_writes: int           # one per step, at last-backward-use
    optimizer_updates: int     # one per step

    def as_suprema(self) -> Suprema:
        return Suprema(reads=self.weight_reads, writes=self.grad_writes,
                       updates=self.optimizer_updates)


def step_suprema(cfg: ModelConfig, *, remat: bool = True
                 ) -> Dict[str, StepAccessPlan]:
    """Exact access bounds per group for one train step."""
    reads = 3 if remat else 2  # fwd, (remat-fwd), bwd
    plan: Dict[str, StepAccessPlan] = {}
    for gi, group in enumerate(cfg.groups):
        plan[f"g{gi}"] = StepAccessPlan(reads, 1, 1)
    plan["embed"] = StepAccessPlan(2, 1, 1)   # in-embed + logits head (tied)
    plan["final_norm"] = StepAccessPlan(reads, 1, 1)
    return plan


def release_points(cfg: ModelConfig) -> Dict[str, str]:
    """Where each group's gradient reaches its write supremum — i.e. where
    the early-release (reduce-scatter) fires. Groups release in reverse
    group order during backward; within a scanned group, per-iteration."""
    order = {}
    n = len(cfg.groups)
    for gi in range(n):
        order[f"g{gi}"] = (f"backward scan iteration of group {gi} "
                           f"(fires {n - gi}-th from step end, per layer)")
    return order
