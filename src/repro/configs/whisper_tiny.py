"""Whisper-tiny [arXiv:2212.04356; unverified].

Encoder-decoder; the conv frontend is a STUB: input_specs() provides
precomputed 1500-frame embeddings [B, 1500, 384] (per the assignment the
backbone only is modeled). 4 encoder + 4 decoder layers, GELU MLPs.
"""
from repro.models.config import LayerGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51_865,
    groups=(LayerGroup(("enc",), 4), LayerGroup(("dec",), 4)),
    ffn_kind="gelu",
    enc_seq=1500,
    tie_embeddings=True,
    frontend="audio",
))
