"""One module per assigned architecture; each registers its ModelConfig.

``repro.models.config.get_config(name)`` lazily imports all of these.
"""
