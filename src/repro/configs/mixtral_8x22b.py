"""Mixtral 8x22B [arXiv:2401.04088; hf].

8-expert top-2 MoE FFN, sliding-window attention (4096), GQA 48/8. The SWA
window bounds the decode KV cache, so this arch runs long_500k.
"""
from repro.models.config import LayerGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    groups=(LayerGroup(("local",), 56),),
    attn_window=4096,
    ffn_kind="moe",
    n_experts=8,
    top_k=2,
    moe_d_ff=16384,
    tie_embeddings=False,
    rope_theta=1_000_000.0,
))
