"""RWKV-6 "Finch" 3B [arXiv:2404.05892; hf].

Attention-free: data-dependent-decay WKV recurrence + squared-ReLU channel
mixing. O(1) state per layer, so this arch serves the long_500k cell.
"""
from repro.models.config import LayerGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    d_model=2560,
    n_heads=40,            # 2560 / 64 WKV heads
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    groups=(LayerGroup(("rwkv",), 32),),
    rwkv_head_dim=64,
    ffn_kind="swiglu",     # unused by rwkv blocks (cmix is built in)
    tie_embeddings=False,
))
