"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family; hf].

128 experts, top-8 routing, per-expert d_ff 1536, qk_norm, GQA 64/4.
"""
from repro.models.config import LayerGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151_936,
    groups=(LayerGroup(("attn",), 94),),
    qk_norm=True,
    ffn_kind="moe",
    n_experts=128,
    top_k=8,
    moe_d_ff=1536,
    tie_embeddings=False,
    rope_theta=1_000_000.0,
))
