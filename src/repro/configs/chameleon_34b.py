"""Chameleon-34B backbone [arXiv:2405.09818; unverified].

Early-fusion VLM: image content arrives as VQ-VAE codebook tokens inside the
65536-entry vocabulary, so the backbone is a pure decoder LM; the modality
frontend (VQ tokenizer) is a stub per the assignment. Chameleon-34B uses
qk-norm for stability.
"""
from repro.models.config import LayerGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="chameleon-34b",
    family="vlm",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    groups=(LayerGroup(("attn",), 48),),
    qk_norm=True,
    ffn_kind="swiglu",
    tie_embeddings=False,
    rope_theta=10_000.0,
    frontend="patch",
))
