"""Phi-4-mini 3.8B [arXiv:2412.08905; hf].

RoPE (partial rotary 0.75), SwiGLU, GQA 24/8, 200k vocab.
"""
from repro.models.config import LayerGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200_064,
    groups=(LayerGroup(("attn",), 32),),
    rotary_pct=0.75,
    ffn_kind="swiglu",
    tie_embeddings=True,
    rope_theta=10_000.0,
))
