"""Gemma-2 2B [arXiv:2408.00118; hf].

Local(4096-window)/global alternating attention, GeGLU, logit soft-capping
(attn 50.0, final 30.0), sqrt(d) embedding scaling, tied embeddings.
"""
from repro.models.config import LayerGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-2b",
    family="dense",
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256_000,
    groups=(LayerGroup(("local", "attn"), 13),),   # 26 layers alternating
    attn_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    ffn_kind="geglu",
    tie_embeddings=True,
    embed_scale=True,
))
