"""Qwen3-4B [hf:Qwen/Qwen3-8B family; hf]. qk_norm, GQA 32/8."""
from repro.models.config import LayerGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-4b",
    family="dense",
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab=151_936,
    groups=(LayerGroup(("attn",), 36),),
    qk_norm=True,
    ffn_kind="swiglu",
    tie_embeddings=True,
    rope_theta=1_000_000.0,
))
