"""RecurrentGemma-9B [arXiv:2402.19427; unverified].

Griffin hybrid: RG-LRU recurrent blocks and local (2048-window) attention in
a 2:1 pattern; 38 layers = 12×(rec,rec,local) + 2 rec. Bounded window +
O(1) recurrent state -> runs long_500k.
"""
from repro.models.config import LayerGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256_000,
    groups=(LayerGroup(("rec", "rec", "local"), 12),
            LayerGroup(("rec",), 2)),
    attn_window=2048,
    ffn_kind="geglu",
    rglru_width=4096,
    conv1d_width=4,
    tie_embeddings=True,
    embed_scale=True,
))
