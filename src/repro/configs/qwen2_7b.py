"""Qwen2-7B [arXiv:2407.10671; hf].

GQA 28/4 with QKV bias, SwiGLU, 152k vocab. 28 query heads do not divide the
16-way TP axis: the PartitionPlan zero-pads to 32 (exactness tested).
"""
from repro.models.config import LayerGroup, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-7b",
    family="dense",
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152_064,
    groups=(LayerGroup(("attn",), 28),),
    qkv_bias=True,
    ffn_kind="swiglu",
    tie_embeddings=False,
    rope_theta=1_000_000.0,
))
