"""Lease-based ownership + epoch fencing (DESIGN.md §10).

Every replicated object's primary holds a time-bounded, epoch-fenced
ownership *lease* over it. The lease is renewed with one-way messages to
the object's follower chain riding the existing reaper cadence
(``NodeCore.reap_stale``): real time on TCP, the virtual clock under
simnet — so renewal schedules are deterministic per seed.

Safety argument, in the model's terms:

* **Durations, never absolute times, cross the wire.** ``time.monotonic``
  is per-process on TCP; a follower that receives ``lease_renew`` with a
  ``ttl`` records ``promise_until = follower_now + ttl`` — which, because
  the message spent time in flight, ends strictly *later* than the
  primary's own ``expires = send_time + ttl``. The safe direction: the
  primary self-fences before any follower's promise lapses.
* **Self-fencing.** A primary that sent renewals and reached ``expires``
  without a quorum of follower acks *fences*: it stops granting versions
  (``check_grant`` raises), refuses commit finalization, and refuses
  non-transactional reads — so a partitioned old primary can neither ack
  unreplicated commits nor serve stale state while a promoted follower
  moves on. Fencing requires *evidence of refusal* (an unanswered renewal
  round); a lease that merely lapsed while the node was idle (the reaper
  disarms with no sessions) re-arms by a **quorum-of-chain
  re-acknowledgement**: the first grant after an idle lapse starts a
  renewal round and *refuses to serve* (``LeaseRearming`` — the op
  handler parks outside the locks and retries) until every live follower
  re-acks the epoch, so a primary that was superseded while idle learns
  the successor's higher epoch *before* acting, not after.
* **Promise = promotion refusal.** A follower holding a live promise
  answers ``lease_acquire``/``promote`` with *busy* until the promise
  lapses; by construction the old primary fenced before that, so no two
  nodes ever act as primary for one object in the same epoch
  (split-brain freedom — auditable via :func:`set_split_brain_auditor`).
* **Epoch fencing.** Promotion and migration bump the epoch. A fenced
  primary keeps retrying renewals; an ack reporting a *higher* epoch is
  proof a successor exists: the fence becomes permanent and the binding
  turns into a redirect tombstone clients follow without reconnecting.
* **Elastic membership.** A follower whose renewal *send* fails
  (crash-stop: the node is gone, not silent) is removed from the lease
  quorum — a dead follower must not wedge a live primary, and no
  promotion can originate from a dead node. Silence (sends succeed,
  acks never come — a partition) is what fences.

Ownership *migration* (the drain-barrier in ``NodeCore._do_migrate``)
reuses the same epoch machinery: the target binds at ``epoch + 1`` and
the old primary keeps a redirect tombstone, exactly like a permanent
fence that knows its successor.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.core.api import RemoteObjectFailure

#: Lease duration (seconds; virtual under simnet). Renewal fires at the
#: half-life, so one full renewal round trip fits well inside the window;
#: the promotion busy-retry loop in ``ensure_primary`` (60 x 20 ms) spans
#: more than one TTL, so a client outlasts any promise it must wait out.
LEASE_TTL = 1.0


class LeaseFencedError(RemoteObjectFailure):
    """Raised by a self-fenced primary instead of acting as one.

    Clients treat it like a dead home node: fail over (the follower chain
    either holds a live promise — retried as *busy* — or promotes).
    """

    def __init__(self, name: str, epoch: int, node: str = "?"):
        super().__init__(
            f"lease for {name!r} (epoch {epoch}) is fenced at {node}")
        self.name = name
        self.epoch = epoch
        self.node = node

    def __reduce__(self):   # multi-arg ctor: survive the wire's pickle
        return (LeaseFencedError, (self.name, self.epoch, self.node))


class ObjectMovedError(RemoteObjectFailure):
    """Epoch-fenced redirect: the object migrated to ``target``.

    Carries everything the client needs to re-point its binding without
    reconnecting: the new home address, the new epoch, and the new
    follower chain.
    """

    def __init__(self, name: str, target: str, epoch: int,
                 followers: Tuple[str, ...] = ()):
        super().__init__(f"object {name!r} moved to {target} "
                         f"(epoch {epoch})")
        self.name = name
        self.target = target
        self.epoch = epoch
        self.followers = list(followers)

    def __reduce__(self):   # multi-arg ctor: survive the wire's pickle
        return (ObjectMovedError,
                (self.name, self.target, self.epoch, tuple(self.followers)))


class LeaseRearming(Exception):
    """Internal (never crosses the wire): an idle-lapsed lease is
    re-arming and must not serve until the quorum-of-chain
    re-acknowledgement round completes. The op handler waits on
    ``event`` OUTSIDE the header/lease locks, then retries
    ``check_grant`` — which either serves (round completed), raises the
    fence (round refused/unanswered), or re-raises this (still in
    flight)."""

    def __init__(self, name: str, event: threading.Event):
        super().__init__(f"lease for {name!r} is re-arming")
        self.name = name
        self.event = event


# -- split-brain auditor (sweep invariant hook) ------------------------------
_auditor: Optional[Callable[[str, int, str], None]] = None


def set_split_brain_auditor(fn: Optional[Callable[[str, int, str], None]]
                            ) -> None:
    """Install ``fn(name, epoch, node_name)``, called every time a node
    *acts as primary* for ``name`` at ``epoch`` (grants a version, binds,
    promotes, or accepts a migration). The simsweep invariant asserts no
    ``(name, epoch)`` is ever acted on by two nodes."""
    global _auditor
    _auditor = fn


def _audit(name: str, epoch: int, node: str) -> None:
    fn = _auditor
    if fn is not None:
        fn(name, epoch, node)


class _Owned:
    """Primary-side lease state for one object."""

    __slots__ = ("epoch", "expires", "awaiting", "renew_sent", "fenced",
                 "rearm")

    def __init__(self, epoch: int, expires: float):
        self.epoch = epoch
        self.expires = expires
        self.awaiting: Set[str] = set()   # followers whose ack is pending
        self.renew_sent: float = -1.0     # -1: no renewal round in flight
        self.fenced = False
        #: idle-lapse re-arm barrier: set when the quorum re-ack round
        #: resolves (completed, self-renewed, or fenced); None otherwise
        self.rearm: Optional[threading.Event] = None


class LeaseManager:
    """Per-node lease table: primary-side owned leases, follower-side
    promises, and redirect tombstones for migrated/moved objects.

    ``core`` is the hosting :class:`~repro.net.server.NodeCore`; the only
    surface used is ``address``, ``node_name``, ``_clock``, ``_peer`` and
    ``replication.followers`` — so the manager is transport-blind and the
    test stubs stay valid.
    """

    def __init__(self, core, ttl: float = LEASE_TTL):
        self.core = core
        self.ttl = ttl
        self.lock = threading.RLock()
        self.owned: Dict[str, _Owned] = {}
        #: follower-side promises: name -> (epoch, until, primary_addr)
        self.promises: Dict[str, Tuple[int, float, str]] = {}
        #: redirect tombstones: name -> (target_addr, epoch, followers)
        self.moved: Dict[str, Tuple[str, int, List[str]]] = {}
        #: crash-stop departures observed while renewing (elastic
        #: membership: dead followers leave the quorum, never re-join)
        self.departed: Set[str] = set()
        self.n_renews = 0        # renewal one-ways sent (bench metric)
        self.n_fences = 0
        self.n_acks = 0

    # -- primary side ---------------------------------------------------------
    def grant_local(self, name: str, epoch: int) -> None:
        """This node becomes (or confirms itself as) primary for ``name``
        at ``epoch``: bind, promotion, or migration-in."""
        now = self.core._clock()
        with self.lock:
            self.owned[name] = _Owned(epoch, now + self.ttl)
            self.promises.pop(name, None)
            self.moved.pop(name, None)
        wal = getattr(self.core, "wal", None)
        if wal is not None:
            wal.lease(name, epoch)
        _audit(name, epoch, self.core.node_name)

    def drop_local(self, name: str, target: str, epoch: int,
                   followers: List[str]) -> None:
        """Ownership left this node: keep an epoch-fenced redirect."""
        with self.lock:
            self.owned.pop(name, None)
            self.moved[name] = (target, epoch, list(followers))
        wal = getattr(self.core, "wal", None)
        if wal is not None:
            wal.tombstone(name, target, epoch, list(followers))

    def epoch_of(self, name: str) -> int:
        with self.lock:
            o = self.owned.get(name)
            return o.epoch if o is not None else -1

    def _followers(self, name: str) -> List[str]:
        chain = self.core.replication.followers.get(name, ())
        return [a for a in chain if a not in self.departed]

    @staticmethod
    def _rearm_done(o: _Owned) -> None:
        """The idle-lapse re-ack round resolved (quorum ack, self-renew,
        or fence): wake the parked grant attempts so they retry."""
        if o.rearm is not None:
            o.rearm.set()
            o.rearm = None

    def _send_renewals(self, name: str, o: _Owned, now: float) -> None:
        """One renewal round: one-way ``lease_renew`` to every live
        follower. Caller holds ``self.lock``."""
        targets = self._followers(name)
        if not targets:
            # no quorum to consult: self-renew (unreplicated object, or
            # every follower provably departed — crash-stop)
            o.expires = now + self.ttl
            o.renew_sent = -1.0
            o.awaiting.clear()
            o.fenced = False
            self._rearm_done(o)
            return
        o.renew_sent = now
        o.awaiting = set(targets)
        for addr in targets:
            try:
                self.core._peer(addr).notify(
                    "lease_renew", name=name, epoch=o.epoch, ttl=self.ttl,
                    primary=self.core.address)
                self.n_renews += 1
            except Exception:  # noqa: BLE001 - crash-stop: follower is gone
                self.departed.add(addr)
                o.awaiting.discard(addr)
        if not o.awaiting:          # every follower departed mid-round
            o.expires = now + self.ttl
            o.renew_sent = -1.0
            o.fenced = False
            self._rearm_done(o)

    def tick(self, now: float) -> None:
        """Renewal/fencing pass, riding ``reap_stale`` (the reaper thread
        on TCP; the virtual-clock reaper event under simnet)."""
        with self.lock:
            for name, o in self.owned.items():
                if name in self.moved:
                    continue
                if o.renew_sent >= 0 and o.awaiting and now >= o.expires:
                    # a full renewal round went unanswered: refusal
                    # evidence — fence (kept retrying below; acks with our
                    # epoch un-fence, a higher epoch makes it permanent)
                    if not o.fenced:
                        o.fenced = True
                        self.n_fences += 1
                        self._trace_fence(name, o.epoch)
                        self._rearm_done(o)   # waiters retry → fence
                    self._send_renewals(name, o, now)
                elif o.renew_sent < 0 and now >= o.expires - self.ttl / 2:
                    self._send_renewals(name, o, now)

    def on_renew(self, name: str, epoch: int, ttl: float,
                 primary: str) -> None:
        """Follower side of ``lease_renew``: record the promise, ack."""
        now = self.core._clock()
        ok, cur = True, epoch
        with self.lock:
            mine = self.owned.get(name)
            if mine is not None and mine.epoch > epoch:
                ok, cur = False, mine.epoch      # I superseded you
            else:
                pe, pu, pp = self.promises.get(name, (-1, -1.0, ""))
                if pe > epoch:
                    ok, cur = False, pe          # promised to a successor
                else:
                    self.promises[name] = (epoch, now + ttl, primary)
        try:
            self.core._peer(primary).notify(
                "lease_ack", name=name, epoch=epoch, ok=ok, cur_epoch=cur,
                node=self.core.address)
        except Exception:  # noqa: BLE001 - primary died; its lease lapses
            pass

    def on_ack(self, name: str, epoch: int, ok: bool, cur_epoch: int,
               node: str) -> None:
        """Primary side of ``lease_ack``."""
        deposed = False
        with self.lock:
            o = self.owned.get(name)
            if o is None or o.epoch != epoch:
                return
            self.n_acks += 1
            if not ok and cur_epoch > o.epoch:
                # a successor exists: permanent fence + redirect tombstone
                # (the refusing follower is the best-known successor)
                o.fenced = True
                self.owned.pop(name, None)
                self.moved[name] = (node, cur_epoch, [])
                self._trace_fence(name, o.epoch, permanent=True)
                self._rearm_done(o)   # waiters retry → redirect
                wal = getattr(self.core, "wal", None)
                if wal is not None:
                    wal.tombstone(name, node, cur_epoch, [])
                deposed = True
            else:
                o.awaiting.discard(node)
                if not o.awaiting and o.renew_sent >= 0:
                    o.expires = o.renew_sent + self.ttl
                    o.renew_sent = -1.0
                    o.fenced = False      # quorum re-confirmed this epoch
                    self._rearm_done(o)
        if deposed:
            # Demote into the successor's chain (§11): a deposed primary
            # that only redirects forever leaves the chain one follower
            # short — rejoin it as the tail instead. Runs in the
            # background: the ack handler must not block on the drain.
            demote = getattr(self.core, "_demote_to_follower", None)
            spawn = getattr(self.core, "_spawn_bg", None)
            if demote is not None and spawn is not None:
                spawn(lambda: demote(name, node), name=f"demote-{name}")

    def check_grant(self, name: str) -> None:
        """Primary-side act-as-primary check: called before granting a
        version, finalizing a commit, or serving a non-transactional
        read. Raises the redirect or the fence; silently re-arms an
        idle-lapsed lease (see module docstring)."""
        now = self.core._clock()
        with self.lock:
            m = self.moved.get(name)
            if m is not None:
                raise ObjectMovedError(name, m[0], m[1], tuple(m[2]))
            o = self.owned.get(name)
            if o is None:
                return                # unleased (e.g. legacy bind path)
            if o.fenced:
                # Retry one round before refusing — the same healing
                # ``tick`` performs: a fence whose refusal evidence was a
                # follower that has since *crash-stopped* (its send is now
                # refused) departs the quorum here and self-renews; a mere
                # partition (silent) keeps us fenced until a quorum ack or
                # a successor's higher epoch (permanent) arrives.
                self._send_renewals(name, o, now)
                if o.fenced:
                    raise LeaseFencedError(name, o.epoch,
                                           self.core.node_name)
            if now >= o.expires:
                if o.renew_sent >= 0 and o.awaiting:
                    o.fenced = True   # unanswered round: fence lazily
                    self.n_fences += 1
                    self._trace_fence(name, o.epoch)
                    self._rearm_done(o)
                    # Same healing round as the fenced branch above: if
                    # the silence was a follower that has since crash-
                    # stopped (refused send), it departs and we self-renew
                    # instead of refusing forever; a silent partition
                    # keeps the fence.
                    self._send_renewals(name, o, now)
                    if o.fenced:
                        raise LeaseFencedError(name, o.epoch,
                                               self.core.node_name)
                else:
                    # idle lapse (reaper was disarmed): start a renewal
                    # round and refuse to serve until the chain re-acks
                    # this epoch (quorum-of-chain re-acknowledgement —
                    # a successor elected while we idled answers with
                    # its higher epoch, turning this into a redirect
                    # instead of a stale grant)
                    o.expires = now + self.ttl
                    self._send_renewals(name, o, now)
                    if o.renew_sent >= 0 and o.rearm is None:
                        o.rearm = threading.Event()
            if o.rearm is not None:
                # a re-ack round is still in flight: not serving yet
                raise LeaseRearming(name, o.rearm)
            epoch = o.epoch
        _audit(name, epoch, self.core.node_name)

    def promise_busy(self, name: str) -> bool:
        """Follower side: is a promotion/acquisition refused right now
        because the current primary's promise is still live?"""
        now = self.core._clock()
        with self.lock:
            pe, pu, _pp = self.promises.get(name, (-1, -1.0, ""))
            return pu > now

    def promised_primary(self, name: str) -> Optional[str]:
        """The primary address behind a still-live promise, or ``None``."""
        now = self.core._clock()
        with self.lock:
            pe, pu, pp = self.promises.get(name, (-1, -1.0, ""))
            return pp if pu > now else None

    def void_promise(self, name: str, primary: str) -> None:
        """Crash-stop evidence arrived: ``primary`` is provably dead (its
        connection is *refused*, not silent), so the promise it holds can
        never be exercised again — void it and let takeover proceed."""
        with self.lock:
            pe, pu, pp = self.promises.get(name, (-1, -1.0, ""))
            if pp == primary:
                self.promises.pop(name, None)

    def on_grant(self, name: str, epoch: int, primary: str) -> bool:
        """Follower side of the *synchronous* ``lease_grant`` sent by a
        freshly promoted/acquiring primary: acknowledge the new epoch
        (quorum-of-chain acknowledgement). Refuse only a stale epoch."""
        now = self.core._clock()
        with self.lock:
            pe, pu, _pp = self.promises.get(name, (-1, -1.0, ""))
            if pe > epoch:
                return False
            mine = self.owned.get(name)
            if mine is not None and mine.epoch >= epoch:
                return False
            self.promises[name] = (epoch, now + self.ttl, primary)
        return True

    def stats(self) -> Dict[str, int]:
        with self.lock:
            fenced = sum(1 for o in self.owned.values() if o.fenced)
            return {"owned": len(self.owned), "fenced": fenced,
                    "moved": len(self.moved), "renews": self.n_renews,
                    "acks": self.n_acks, "fences": self.n_fences}

    def _trace_fence(self, name: str, epoch: int,
                     permanent: bool = False) -> None:
        tr = getattr(self.core, "obs_tracer", None)
        if tr is not None:
            from repro.obs import txtrace
            if txtrace.enabled:
                tr.instant("lease_fence",
                           detail=f"{name}@e{epoch}"
                                  f"{'!' if permanent else ''}",
                           sev=txtrace.WARN)
