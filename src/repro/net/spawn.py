"""Subprocess helpers: launch real node-server processes (DESIGN.md §3.1).

Used by ``benchmarks/eigenbench.py --transport=tcp``, the distributed
quickstart, and the transport tests: spawns ``python -m repro.net.server``
with an OS-assigned port, parses the ``LISTENING host:port`` announcement,
and hands back a :class:`ServerHandle` that can stop the process cleanly
(shutdown RPC first, SIGTERM/kill as fallback).

This module is TCP-only on purpose: a "node" of the deterministic
simulation transport is an in-process :class:`~repro.net.simnet.SimNode`
(no subprocess to spawn) — build those with
:func:`repro.net.simnet.build_simnet` instead. Both end up behind the
same client-side :class:`~repro.net.transport.Transport` interface.
"""
from __future__ import annotations

import os
import subprocess
import sys
import threading
from pathlib import Path
from typing import List, Optional, Sequence

from .client import NodeClient

_SRC_DIR = str(Path(__file__).resolve().parents[2])   # .../src


class ServerHandle:
    """A running node-server subprocess."""

    def __init__(self, proc: subprocess.Popen, address: str, name: str):
        self.proc = proc
        self.address = address
        self.name = name
        self._client: Optional[NodeClient] = None

    @property
    def client(self) -> NodeClient:
        if self._client is None:
            self._client = NodeClient(self.address)
        return self._client

    def stop(self, grace: float = 3.0) -> None:
        if self.proc.poll() is None:
            try:
                self.client.call("shutdown")
            except Exception:  # noqa: BLE001 - already dead is fine
                pass
            try:
                self.proc.wait(timeout=grace)
            except subprocess.TimeoutExpired:
                self.proc.terminate()
                try:
                    self.proc.wait(timeout=grace)
                except subprocess.TimeoutExpired:
                    self.proc.kill()
                    self.proc.wait()
        if self._client is not None:
            self._client.close()

    def kill(self) -> None:
        """Crash-stop the server process (for §3.4 failure testing)."""
        if self.proc.poll() is None:
            self.proc.kill()
            self.proc.wait()

    def __enter__(self) -> "ServerHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


def spawn_server(name: str = "node0", *, host: str = "127.0.0.1",
                 port: int = 0,
                 monitor_timeout: float = 2.0, monitor_poll: float = 0.05,
                 workers: int = 1, extra_paths: Sequence[str] = (),
                 wal_dir: Optional[str] = None,
                 startup_timeout: float = 20.0) -> ServerHandle:
    """Spawn one node-server process and wait for its announcement.

    ``extra_paths`` are appended to the server's ``sys.path`` so that
    classes of objects bound over the wire (pickled by reference) can be
    imported on the home node.

    ``port=0`` (the default) lets the OS pick; a fixed port plus a
    ``wal_dir`` is the §11 restart recipe — SIGKILL the process, spawn it
    again under the same name/port/wal_dir, and it replays its ledger and
    rejoins its chains under the old identity.
    """
    cmd: List[str] = [
        sys.executable, "-u", "-m", "repro.net.server",
        "--name", name, "--host", host, "--port", str(port), "--announce",
        "--monitor-timeout", str(monitor_timeout),
        "--monitor-poll", str(monitor_poll),
        "--workers", str(workers),
    ]
    if wal_dir is not None:
        cmd += ["--wal-dir", str(wal_dir)]
    for p in extra_paths:
        cmd += ["--path", str(p)]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [_SRC_DIR, *map(str, extra_paths),
         *filter(None, [env.get("PYTHONPATH")])])
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.DEVNULL, env=env, text=True)
    # readline() blocks, so the deadline is enforced from a reader thread —
    # a child that hangs before announcing must not stall the parent.
    found: dict = {}

    def _reader() -> None:
        for line in proc.stdout:
            if line.startswith("LISTENING "):
                found["address"] = line.split(None, 1)[1].strip()
                return

    t = threading.Thread(target=_reader, daemon=True)
    t.start()
    t.join(startup_timeout)
    if "address" not in found:
        proc.kill()
        proc.wait()
        if proc.returncode not in (None, -9):
            raise RuntimeError(
                f"node server {name!r} died during startup "
                f"(rc={proc.returncode})")
        raise TimeoutError(f"node server {name!r} never announced")
    return ServerHandle(proc, found["address"], name)


def spawn_cluster(n: int, **kw) -> List[ServerHandle]:
    """Spawn ``n`` node servers (``node0`` ... ``node{n-1}``)."""
    handles: List[ServerHandle] = []
    try:
        for i in range(n):
            handles.append(spawn_server(f"node{i}", **kw))
    except BaseException:
        for h in handles:
            h.stop()
        raise
    return handles
