"""Write-ahead ledger + crash-restart recovery (DESIGN.md §11).

Every node appends its durable facts here *before* acknowledging them:
replication tentatives and finals keyed by ``(epoch, seq)``, commit
decisions, lease epochs, chain membership, and redirect tombstones. A
restarting node replays the ledger (truncating a torn tail at the first
bad checksum), rebuilds its pre-crash roles, and rejoins its chains —
see :meth:`Wal.recover` for the replay state machine and
``NodeCore.rejoin_chains`` for the networked half.

Frame format (little-endian)::

    <u32 length> <u32 crc32-of-payload> <payload = pickle(record dict)>

Appends are cheap (one buffered write); durability points — commit
finals, decisions, membership changes — call :meth:`Wal.append` with
``sync=True``, which flushes *every* frame written since the last sync
in one batch (``fsync``-batched group commit). The two counters
``n_appends`` / ``n_syncs`` feed the benchmark metrics
``wal_appends_per_txn`` / ``fsync_batches_per_txn``.

Storage is pluggable: :class:`FileStorage` is a real append-only file
(TCP nodes, opt-in via ``--wal-dir``); :class:`VirtualDisk` is the
deterministic in-memory device simnet hands a node — it survives a
simulated restart and models an *ordered* device on crash: a seeded
prefix of the unsynced writes lands, the next frame may land torn, the
rest is lost.
"""
from __future__ import annotations

import os
import pickle
import struct
import zlib
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Wal", "FileStorage", "VirtualDisk", "Recovered", "replay",
           "DELTA_MAGIC", "encode_delta", "fold_payload"]

_HDR = struct.Struct("<II")

#: Prefix marking a replication payload as a §12 commute *delta* — a
#: pickled entry list to fold into the committed snapshot — rather than a
#: full state snapshot that replaces it. No pickle protocol starts with a
#: NUL byte, so the prefix test can never misfire on a snapshot payload.
DELTA_MAGIC = b"\x00\xc6\x12"


def encode_delta(entries) -> bytes:
    """Wrap a commute-group member's buffered ``(method, args, kwargs)``
    entries as a replication payload (tentative delta, DESIGN.md §12)."""
    return DELTA_MAGIC + pickle.dumps(
        list(entries), protocol=pickle.HIGHEST_PROTOCOL)


def fold_payload(base: bytes, payload: bytes) -> bytes:
    """Resolve a replication payload against the committed snapshot
    ``base``: a snapshot payload replaces it, a commute delta folds into
    it (replay the entries against the unpickled state — the §12 contract
    is that entries of one method class commute, so fold order across
    group members is free)."""
    if not payload.startswith(DELTA_MAGIC):
        return payload
    obj = pickle.loads(base)
    for method, args, kwargs in pickle.loads(payload[len(DELTA_MAGIC):]):
        getattr(obj, method)(*args, **(kwargs or {}))
    return pickle.dumps(obj)


def _frame(record: Dict[str, Any]) -> bytes:
    payload = pickle.dumps(record, protocol=pickle.HIGHEST_PROTOCOL)
    return _HDR.pack(len(payload), zlib.crc32(payload)) + payload


def replay(data: bytes) -> Tuple[List[Dict[str, Any]], int]:
    """Decode ``data`` into records, stopping at the first torn frame.

    Returns ``(records, good)`` where ``good`` is the byte length of the
    intact prefix — everything past it (a partial header, a short
    payload, or a checksum mismatch: the torn tail of a crash mid-write)
    is truncated by the caller before appending resumes.
    """
    records: List[Dict[str, Any]] = []
    off = 0
    n = len(data)
    while off + _HDR.size <= n:
        length, crc = _HDR.unpack_from(data, off)
        start, end = off + _HDR.size, off + _HDR.size + length
        if end > n:
            break                       # short payload: torn tail
        payload = data[start:end]
        if zlib.crc32(payload) != crc:
            break                       # corrupt frame: torn tail
        try:
            records.append(pickle.loads(payload))
        except Exception:  # noqa: BLE001 - undecodable frame: treat as torn
            break
        off = end
    return records, off


# --------------------------------------------------------------------- #
# storage backends                                                      #
# --------------------------------------------------------------------- #
class FileStorage:
    """A real append-only ledger file.

    Writes go straight to the kernel (unbuffered handle), so a SIGKILL
    loses at most what the *device* would lose; ``sync`` is a real
    ``fsync``. ``truncate`` discards a torn tail found at replay.
    """

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "ab", buffering=0)

    def read_all(self) -> bytes:
        with open(self.path, "rb") as f:
            return f.read()

    def truncate(self, good: int) -> None:
        self._f.close()
        with open(self.path, "r+b") as f:
            f.truncate(good)
        self._f = open(self.path, "ab", buffering=0)

    def append(self, data: bytes) -> None:
        self._f.write(data)

    def sync(self) -> None:
        os.fsync(self._f.fileno())

    def close(self) -> None:
        try:
            self._f.close()
        except OSError:
            pass


class VirtualDisk:
    """simnet's deterministic in-memory ledger device.

    ``data`` is the durable (synced) image; ``pending`` holds frames
    written but not yet synced. :meth:`crash` applies ordered-device
    semantics with the simulation's seeded RNG: a prefix of ``pending``
    survives, the next frame may survive *torn* (a random strict prefix
    of its bytes), the rest vanishes. ``halt`` models the device going
    away mid-append (the ``node-mid-wal-append`` injection): once set,
    appends and syncs are no-ops until the node restarts.
    """

    def __init__(self) -> None:
        self.data = bytearray()
        self.pending: List[bytes] = []
        self.halt = False
        #: injection hook (simnet ``node-mid-wal-append``): called after
        #: each frame is written, while the writer is still on-CPU.
        self.on_append = None

    def read_all(self) -> bytes:
        return bytes(self.data)

    def truncate(self, good: int) -> None:
        del self.data[good:]

    def append(self, data: bytes) -> None:
        if self.halt:
            return
        self.pending.append(data)
        if self.on_append is not None:
            self.on_append(self)

    def sync(self) -> None:
        if self.halt:
            return
        for chunk in self.pending:
            self.data += chunk
        self.pending.clear()

    def close(self) -> None:
        pass

    def tear_tail(self, rng) -> None:
        """Corrupt the most recent unsynced frame to a strict prefix
        (the ``node-mid-wal-append`` injection: the append itself is the
        crash point, so the frame can never be whole)."""
        if self.pending:
            last = self.pending[-1]
            self.pending[-1] = last[:rng.randrange(0, len(last))]

    def crash(self, rng) -> None:
        """Crash-time settlement of unsynced writes (ordered device).
        Leaves the device halted — a poisoned handler unwinding after the
        node's death must not leak post-mortem frames into the image the
        restart replays; the restart re-opens it (``SimNet._disk``)."""
        if self.pending:
            k = rng.randint(0, len(self.pending))  # frames [0:k) land whole
            for chunk in self.pending[:k]:
                self.data += chunk
            if k < len(self.pending):
                torn = self.pending[k]
                cut = rng.randrange(0, len(torn)) if torn else 0
                if cut:
                    self.data += torn[:cut]        # frame k lands torn
            self.pending.clear()
        self.halt = True


# --------------------------------------------------------------------- #
# recovery state machine                                                #
# --------------------------------------------------------------------- #
class Recovered:
    """What a replayed ledger says this node *was* (DESIGN.md §11).

    - ``objects``: name -> the last known role + committed snapshot::

        {"role": "primary" | "follower",
         "payload": pickled committed state, "epoch": int, "seq": int,
         "primary": address-or-None, "order": [addr, ...],
         "followers": [addr, ...]}

    - ``decisions``: txn -> "commit" / "abort" (the decision ledger).
    - ``pending``: (txn, name) -> (epoch, seq, payload, head) —
      tentatives with **no** recorded final/drop/decision: undecided at
      crash time, to be resolved against the live chain (or doomed).
    - ``tombstones``: name -> (target, epoch, followers) — redirect
      tombstones to rehydrate so stale client bindings keep redirecting.
    - ``leases``: name -> last granted lease epoch.
    """

    def __init__(self) -> None:
        self.objects: Dict[str, Dict[str, Any]] = {}
        self.decisions: Dict[str, str] = {}
        self.pending: Dict[Tuple[str, str], Tuple[int, int, bytes, Optional[str]]] = {}
        self.tombstones: Dict[str, Tuple[str, int, List[str]]] = {}
        self.leases: Dict[str, int] = {}


class Wal:
    """The per-node write-ahead ledger over a storage backend."""

    def __init__(self, storage) -> None:
        self.storage = storage
        self.n_appends = 0
        self.n_syncs = 0
        self._unsynced = 0
        self.records, good = replay(storage.read_all())
        self.truncated = len(storage.read_all()) - good
        if self.truncated:
            storage.truncate(good)

    # -- appending ------------------------------------------------------
    def append(self, record: Dict[str, Any], sync: bool = False) -> None:
        self.storage.append(_frame(record))
        self.n_appends += 1
        self._unsynced += 1
        if sync:
            self.storage.sync()
            self.n_syncs += 1
            self._unsynced = 0

    # Typed writers: one per durable fact. Appends are buffered; the
    # facts that must not be lost once acknowledged (finals, decisions,
    # membership, leases, tombstones) sync — each sync lands the whole
    # unsynced batch (group commit), so a commit costs at most one
    # fsync however many tentatives preceded it.
    def bind(self, name: str, payload: bytes, followers: List[str],
             epoch: int) -> None:
        self.append({"kind": "bind", "name": name, "payload": payload,
                     "followers": list(followers), "epoch": epoch},
                    sync=True)

    def tentative(self, txn: str, name: str, epoch: int, seq: int,
                  payload: bytes, head: Optional[str]) -> None:
        self.append({"kind": "tentative", "txn": txn, "name": name,
                     "epoch": epoch, "seq": seq, "payload": payload,
                     "head": head})

    def final(self, txn: str, name: str, epoch: int, seq: int) -> None:
        self.append({"kind": "final", "txn": txn, "name": name,
                     "epoch": epoch, "seq": seq}, sync=True)

    def drop(self, txn: str, name: str) -> None:
        self.append({"kind": "drop", "txn": txn, "name": name})

    def decision(self, txn: str, decision: str) -> None:
        self.append({"kind": "decision", "txn": txn, "decision": decision},
                    sync=True)

    def init(self, name: str, primary: str, order: List[str], epoch: int,
             seq: int, payload: bytes) -> None:
        self.append({"kind": "init", "name": name, "primary": primary,
                     "order": list(order), "epoch": epoch, "seq": seq,
                     "payload": payload}, sync=True)

    def membership(self, name: str, order: List[str],
                   followers: List[str]) -> None:
        self.append({"kind": "membership", "name": name,
                     "order": list(order), "followers": list(followers)},
                    sync=True)

    def lease(self, name: str, epoch: int) -> None:
        self.append({"kind": "lease", "name": name, "epoch": epoch},
                    sync=True)

    def tombstone(self, name: str, target: str, epoch: int,
                  followers: List[str]) -> None:
        self.append({"kind": "tombstone", "name": name, "target": target,
                     "epoch": epoch, "followers": list(followers)},
                    sync=True)

    # -- replay ---------------------------------------------------------
    def recover(self) -> Recovered:
        """Fold the replayed records into a :class:`Recovered` image.

        Ordering rules: ``bind``/``init`` reset an object's role and
        committed snapshot; a ``final`` (or a later ``decision: commit``)
        promotes its matching tentative into the committed snapshot iff
        its ``(epoch, seq)`` advances it; tombstones supersede roles
        (the object moved away); epoch monotonicity everywhere.
        """
        rec = Recovered()
        for r in self.records:
            kind = r["kind"]
            if kind == "bind":
                rec.objects[r["name"]] = {
                    "role": "primary", "payload": r["payload"],
                    "epoch": r["epoch"], "seq": 0, "primary": None,
                    "order": [], "followers": list(r["followers"])}
                rec.tombstones.pop(r["name"], None)
            elif kind == "init":
                rec.objects[r["name"]] = {
                    "role": "follower", "payload": r["payload"],
                    "epoch": r["epoch"], "seq": r["seq"],
                    "primary": r["primary"], "order": list(r["order"]),
                    "followers": []}
            elif kind == "tentative":
                rec.pending[(r["txn"], r["name"])] = (
                    r["epoch"], r["seq"], r["payload"], r.get("head"))
            elif kind == "final":
                rec.decisions.setdefault(r["txn"], "commit")
                self._apply_pending(rec, r["txn"], r["name"])
            elif kind == "drop":
                rec.pending.pop((r["txn"], r["name"]), None)
            elif kind == "decision":
                rec.decisions.setdefault(r["txn"], r["decision"])
            elif kind == "membership":
                o = rec.objects.get(r["name"])
                if o is not None:
                    o["order"] = list(r["order"])
                    o["followers"] = list(r["followers"])
            elif kind == "lease":
                rec.leases[r["name"]] = r["epoch"]
            elif kind == "tombstone":
                rec.objects.pop(r["name"], None)
                rec.tombstones[r["name"]] = (
                    r["target"], r["epoch"], list(r["followers"]))
        # Decisions recorded after the tentative settle it at replay end:
        for (txn, name), _t in list(rec.pending.items()):
            d = rec.decisions.get(txn)
            if d == "commit":
                self._apply_pending(rec, txn, name)
            elif d == "abort":
                rec.pending.pop((txn, name), None)
        return rec

    @staticmethod
    def _apply_pending(rec: Recovered, txn: str, name: str) -> None:
        t = rec.pending.pop((txn, name), None)
        o = rec.objects.get(name)
        if t is None or o is None:
            return
        epoch, seq, payload, _head = t
        if (epoch, seq) >= (o["epoch"], o["seq"]):
            # fold_payload: a §12 commute delta folds into the replayed
            # snapshot instead of replacing it (same rule as the live
            # follower's apply — replay must converge to the same state).
            o["payload"] = fold_payload(o["payload"], payload)
            o["epoch"], o["seq"] = epoch, seq

    def close(self) -> None:
        self.storage.close()
