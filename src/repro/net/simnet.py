"""Deterministic simulation transport (simnet) — DESIGN.md §7.

Runs every "node" of an N-node OptSVA-CF deployment inside ONE process
under a **virtual clock**, with a seeded scheduler owning ALL transport
nondeterminism: message delivery order and latency, one-way vs. reply
interleaving, heartbeat and failure-detector timing, and fault injection
(crash-stop a client process at any labeled protocol step, or a home node
at a chosen virtual time). FoundationDB-style: the same seed always
produces the same schedule, so a failing seed *is* a reproducible bug
report — ``trace_text()`` prints the replayable schedule.

How determinism is achieved
---------------------------
Everything inside the simulation executes **serially** under a single run
token:

* *client actors* — workload threads spawned with :meth:`SimNet.spawn`
  that run ordinary :class:`~repro.core.transaction.Transaction` code over
  :class:`SimTransport` endpoints;
* *handler actors* — pooled threads that execute one delivered message
  against a :class:`SimNode` (the transport-independent
  :class:`~repro.net.server.NodeCore` engine — the very same sessions /
  ``_op_*`` dispatch / §3.4 expiry code the TCP server runs).

Exactly one actor runs at a time; every blocking point yields the token
back to the scheduler: RPC awaits (``SimFuture.result``), task joins,
version-condition waits (via :func:`repro.core.versioning.
set_blocking_wait` — the hookable-wait refactor), and dispensing-gate
acquisition (a virtual-time backoff loop). The scheduler resumes exactly
one runnable actor at a time, in a deterministic order, and advances the
virtual clock only by popping the seeded event heap. Since all scheduling
decisions derive from the seeded RNG and the (serial, deterministic)
execution between yield points, the schedule — and therefore the whole
run — replays bit-for-bit.

Message semantics mirror the TCP transport exactly where the protocol
depends on them: per-direction FIFO delivery (latencies are drawn per
message but delivery times are clamped monotone per link — TCP cannot
reorder a connection), one-way messages complete before any later message
of the same link starts (the TCP reader executes them inline), while
requests may park server-side and complete out of order (the worker
pool). Frames are delivered directly — the wire-v3 framing and the
leader/follower demux are TCP-only machinery below the Transport
interface — but every message payload is pickle-roundtripped, so state
isolation between "processes" is real and unpicklable arguments fail
like they would on the wire.

Fault injection (§3.4)
----------------------
:meth:`SimNet.inject_crash` crashes a simulated client process at the
``nth`` occurrence of a named op, ``before_send`` or ``after_send`` —
the labeled protocol steps of interest:

* ``dispense_batch`` after_send  — mid-dispense: the server holds gates
  and a session for a client that no longer exists;
* ``open_call`` after_send      — mid-(chained-)open;
* ``lw_apply`` after_send       — during §2.8.4 last-write application;
* ``commit_chain`` before_send  — the client dies without ever asking for
  a commit: full §3.4 rollback everywhere;
* ``commit_chain`` after_send   — the client dies with the commit request
  in flight: the coordinator decides and drives steps 2-5 autonomously
  (the chained commit decision, DESIGN.md §8 — the old client-driven
  step-5 partial-commit window is CLOSED; the transfer applies everywhere
  or nowhere).

:meth:`SimNet.inject_node_crash` instead crashes a home *node* at the nth
delivery of a chosen op — ``commit_chain`` / ``commit_wave`` /
``commit_decide`` / ``repl_apply`` / ``repl_final`` — exercising the
decision chain's redirect-around-dead-nodes path and the replica chain's
follower promotion at every protocol step.

A crashed client sends nothing further (its cleanup raises
:class:`SimCrash`, a BaseException, so no abort-path RPC can leak out —
crash-stop means *silence*); the server converges via the presence-drop
path or the heartbeat-timeout reaper (the seed decides which), running
the same ``_expire_session`` §3.4 self-rollback as the TCP server.
:meth:`SimNet.crash_node_at` kills a home node at a virtual time instead:
every transport to it fails in-flight work with ``RemoteObjectFailure``
and parked handlers unwind.
"""
from __future__ import annotations

import heapq
import pickle
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import random

from repro.core import versioning
from repro.core.api import RemoteObjectFailure
from repro.core.registry import Registry
from repro.obs import txtrace as _txtrace

from .server import ERR, NodeCore, OK, _WouldBlock, encode_error
from .transport import Transport
from .wal import VirtualDisk, Wal

__all__ = ["SimCrash", "SimDeadlock", "SimNet", "SimNode", "SimTransport",
           "build_simnet"]


class SimCrash(BaseException):
    """Unwinds a crashed simulated client. A BaseException on purpose:
    crash-stop means the client does NOTHING more — not even the abort
    path's cleanup RPCs, which ``except Exception`` handlers would
    otherwise run."""


class SimDeadlock(RuntimeError):
    """The simulation wedged: live actors remain but no event can run.
    Carries the replayable schedule in ``trace``."""

    def __init__(self, msg: str, trace: str):
        super().__init__(f"{msg}\n--- replayable schedule ---\n{trace}")
        self.trace = trace


class _Actor:
    """One token-gated thread inside the simulation."""

    __slots__ = ("name", "kind", "sem", "thread", "finished", "fn",
                 "node", "poisoned", "crashed")

    def __init__(self, name: str, kind: str):
        self.name = name
        self.kind = kind                  # "client" | "handler"
        self.sem = threading.Semaphore(0)
        self.thread: Optional[threading.Thread] = None
        self.finished = False
        self.fn: Optional[Callable[[], None]] = None
        self.node: Optional["SimNode"] = None   # handler's current node
        self.poisoned = False             # node died under this handler
        self.crashed = False              # client unwound via SimCrash


class _Link:
    """One direction of one simulated connection (FIFO, like TCP)."""

    __slots__ = ("queue", "locked", "last_time", "deferred")

    def __init__(self):
        self.queue: List[tuple] = []
        self.locked = False               # a one-way handler is running
        self.last_time = 0.0
        self.deferred = 0                 # pumps swallowed while locked


class SimConn:
    """Server-side view of a client link (duck-types ``_Conn.client_id``)."""

    __slots__ = ("client_id", "transport")

    def __init__(self, transport: "SimTransport"):
        self.client_id = transport.client_id
        self.transport = transport


class SimFuture:
    """Completion handle for one in-flight simulated request; ``result``
    yields the run token to the scheduler until the reply event fires."""

    __slots__ = ("_done", "_value", "_error", "simnet", "abandoned")

    def __init__(self, simnet: "SimNet"):
        self._done = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None
        self.simnet = simnet
        self.abandoned = False

    def set_result(self, value: Any) -> None:
        self._value = value
        self._done.set()

    def set_error(self, error: BaseException) -> None:
        self._error = error
        self._done.set()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._done.is_set():
            if not self.simnet.wait_event(self._done, timeout):
                self.abandoned = True   # its late reply will be dropped
                raise TimeoutError("RPC reply did not arrive in time")
        if self._error is not None:
            raise self._error
        return self._value


class SimTransport(Transport):
    """The simulated client endpoint for ONE (client process, node) pair.

    Implements the narrow :class:`~repro.net.transport.Transport` surface;
    the shared bookkeeping (deferred errors, task waits, liveness sets) is
    the base class's — byte-identical protocol semantics with TCP."""

    scheme = "sim"

    def __init__(self, simnet: "SimNet", node: "SimNode", client_id: str):
        super().__init__(node.address, client_id=client_id)
        self.simnet = simnet
        self.node = node
        self.conn = SimConn(self)
        self.crashed = False
        self._req_ids = 0
        self._pending: Dict[int, SimFuture] = {}
        self.to_server = _Link()
        self.to_client = _Link()
        self._hb_armed = False
        self.affinity = simnet._affinity.get(client_id)
        simnet._register_transport(self)

    # -- message primitives ---------------------------------------------------
    def _check_sendable(self, op: str) -> None:
        if self.crashed:
            raise SimCrash(f"{self.client_id} is crash-stopped")
        self.simnet._check_injection(self, op, "before_send")
        if not self.alive or not self.node.alive:
            # node.alive also covers a transport built AFTER the node
            # crashed (e.g. a fresh server-to-server chain link) — the
            # TCP analogue is the refused connect.
            raise RemoteObjectFailure(
                f"node server {self.address} is unreachable (crash-stop)")

    def call_async(self, op: str, **kwargs: Any) -> SimFuture:
        self._check_sendable(op)
        fut = SimFuture(self.simnet)
        self._req_ids += 1
        req_id = self._req_ids
        with self._lock:
            self.n_rpc += 1
            self._pending[req_id] = fut
        self.simnet._send(self, req_id, op, kwargs, fut)
        self.simnet._check_injection(self, op, "after_send")
        return fut

    def notify(self, op: str, **kwargs: Any) -> None:
        self._check_sendable(op)
        self._oneway.inc()   # exact, lock-free (per-thread cells)
        self.simnet._send(self, None, op, kwargs, None)
        self.simnet._check_injection(self, op, "after_send")

    def _obs_tracer(self):
        # Determinism: every sim-side span must read the virtual clock.
        # Actor/handler threads carry their own bound tracer; calls from
        # unbound threads (topology setup on the host thread) fall back
        # to this client's own virtual-clock site instead of the
        # process-wide monotonic one.
        return (_txtrace.thread_tracer()
                or _txtrace.tracer(f"client:{self.client_id}",
                                   clock=self.simnet.now))

    def join_task(self, txn_uid: str, name: str):
        """Join a home-node task: yield to the scheduler until the pushed
        ``task_done`` note resolves the wait (virtual time — no grace
        polling needed; a lost push is impossible in-sim short of a crash,
        and crashes fail the wait)."""
        if self.crashed:
            raise SimCrash(f"{self.client_id} is crash-stopped")
        wait = self._task_wait(txn_uid, name)
        self.simnet.wait_event(wait.done, None)
        return wait

    def register_txn(self, txn_uid: str) -> None:
        if self.crashed:
            raise SimCrash(f"{self.client_id} is crash-stopped")
        with self._lock:
            self._active_txns.add(txn_uid)
        self.simnet._arm_heartbeat(self)

    def sleep(self, seconds: float) -> None:
        """Transport-clocked backoff (failover grace / promote retries):
        virtual time inside the simulation, a short native wait outside."""
        self.simnet.sleep(seconds)

    def failover_grace(self) -> float:
        """Virtual-clock failure-detection grace, derived from the
        simulated link latencies (100x the worst one-way) instead of a
        wall-clock constant — so sweeps with stretched latencies keep the
        detection-time >> flight-time assumption by construction."""
        return max(100.0 * self.simnet.latency[1], 1e-4)

    def close(self) -> None:
        self.alive = False

    # -- inbound (called by the scheduler, under the token) -------------------
    def _deliver_reply(self, req_id: int, status: str, value: Any) -> None:
        with self._lock:
            fut = self._pending.pop(req_id, None)
        if fut is None or fut.abandoned:
            self.simnet._trace(f"drop {self.node.node_name}->"
                               f"{self.client_id} reply#{req_id} (late)")
            return
        self.n_inline += 1
        if status == OK:
            fut.set_result(value)
        else:
            fut.set_error(value)

    # -- failure --------------------------------------------------------------
    def _mark_dead(self, reason: str) -> None:
        """The home node crash-stopped: fail all in-flight work (§3.4)."""
        with self._lock:
            self.alive = False
            pending = list(self._pending.values())
            self._pending.clear()
            waits = list(self._tasks.values())
        err = RemoteObjectFailure(
            f"node server {self.address} is unreachable ({reason})")
        for fut in pending:
            fut.set_error(err)
        self._fail_task_waits(waits, err)

    def _crash(self) -> None:
        """This simulated client process crash-stopped."""
        self.crashed = True
        err = SimCrash(f"{self.client_id} crashed")
        with self._lock:
            pending = list(self._pending.values())
            self._pending.clear()
            waits = list(self._tasks.values())
        for fut in pending:
            fut.set_error(err)
        self._fail_task_waits(waits, err)

    def __repr__(self) -> str:  # pragma: no cover
        return f"SimTransport({self.client_id}->{self.node.node_name})"


class SimNode(NodeCore):
    """A simulated home node: the full NodeCore protocol engine, its own
    private :class:`Registry` (state isolation, like a separate process),
    no sockets, no real-time threads — expiry runs off virtual-clock
    reaper events and pushes are scheduler deliveries."""

    #: determinism: gate-open kickoff tasks run on the delivering actor.
    INLINE_KICKOFF_TASKS = True

    def __init__(self, simnet: "SimNet", node_name: str, *,
                 monitor_timeout: float, monitor_poll: float):
        # Durability is always on under simnet: the node's ledger lives on
        # the net's per-name VirtualDisk, which survives a simulated
        # restart — appends are local (zero messages), so fault-free
        # message plans are byte-identical with and without it.
        super().__init__(node_name, registry=Registry(),
                         monitor_timeout=monitor_timeout,
                         monitor_poll=monitor_poll,
                         clock=simnet.now,
                         wal=Wal(simnet._disk(node_name)))
        self.simnet = simnet
        self.alive = True
        self._reaper_armed = False

    @property
    def address(self) -> str:
        return f"sim://{self.node_name}"

    # -- transport hooks ------------------------------------------------------
    def _queue_note(self, conn: SimConn, note: dict) -> None:
        self.simnet._send_note(self, conn.transport, note)

    def _push_target(self, conn: Optional[SimConn],
                     client_id: str) -> Optional[SimConn]:
        if conn is not None and conn.client_id == client_id:
            return conn
        t = self.simnet._transport_for(client_id, self.node_name)
        return t.conn if t is not None else None

    def _gate_acquire(self, gate: threading.Lock, nb: bool = False) -> None:
        if nb:  # pragma: no cover - sim has no reader fast path
            if not gate.acquire(blocking=False):
                raise _WouldBlock
            return
        # Virtual-time backoff instead of a real block: the gate holder is
        # another parked actor that can only progress once we yield.
        while not gate.acquire(blocking=False):
            self.simnet.sleep(0.0005)

    def _peer(self, address: str) -> SimTransport:
        """Server-to-server link for chained dispensing (§2.10.2)."""
        peer = self._peers.get(address)
        if peer is None or not peer.alive:
            node = self.simnet.node_by_address(address)
            peer = SimTransport(self.simnet, node,
                                client_id=f"peer:{self.node_name}")
            self._peers[address] = peer
        return peer

    def _spawn_bg(self, fn: Callable[[], None], name: str = "bg") -> None:
        """Background jobs (migration drains) run on a handler actor: they
        may block at virtual-time waits, and must never block the
        scheduler loop itself. Outside a run (setup/teardown execute in
        ``_immediate`` mode) there is no scheduler to resume an actor —
        run the job inline on the caller like every other immediate op."""
        if not self.simnet._running:
            fn()
            return
        self.simnet._spawn_handler(fn, self)

    # -- tracing hooks --------------------------------------------------------
    def _op_dispense_batch(self, *args: Any, **kwargs: Any):
        out = super()._op_dispense_batch(*args, **kwargs)
        self.simnet._arm_reaper(self)
        return out

    def _expire_session(self, session) -> None:
        self.simnet._trace(
            f"expire {self.node_name} "
            f"txn={self.simnet._txn_label(session.txn_uid)}")
        super()._expire_session(session)


class SimNet:
    """The deterministic simulation: virtual clock + seeded scheduler +
    nodes + transports + trace. See the module docstring."""

    def __init__(self, seed: int, *, latency: Tuple[float, float] = (50e-6,
                                                                     500e-6),
                 heartbeat_interval: float = 0.25,
                 monitor_timeout: float = 1.0, monitor_poll: float = 0.25):
        self.seed = seed
        self.rng = random.Random(f"simnet:{seed}")   # str-seeding: stable sha512
        self.latency = latency
        self.heartbeat_interval = heartbeat_interval
        self.monitor_timeout = monitor_timeout
        self.monitor_poll = monitor_poll
        self._now = 0.0
        self._seq = 0
        self._events: List[tuple] = []      # (time, seq, kind, payload)
        self._watchers: List[list] = []     # [actor, event, active]
        self._trace_lines: List[str] = []
        self._txn_labels: Dict[str, str] = {}
        self._nodes: Dict[str, SimNode] = {}
        self._disks: Dict[str, VirtualDisk] = {}   # survive node restarts
        self._transports: Dict[Tuple[str, str], SimTransport] = {}
        self._clients: List[_Actor] = []
        self._idle_handlers: List[_Actor] = []
        self._all_handlers: List[_Actor] = []
        self._injections: List[dict] = []
        self._op_counts: Dict[Tuple[str, str], int] = {}
        self._node_injections: List[dict] = []
        self._node_op_counts: Dict[Tuple[str, str], int] = {}
        self._crashed_clients: Dict[str, str] = {}   # client_id -> label
        self.fired_injections: List[str] = []
        self._partitions: List[dict] = []   # active cuts: {a, b, label}
        self._affinity: Dict[str, str] = {}   # client_id -> home address
        self._sched_sem = threading.Semaphore(0)
        self._tl = threading.local()
        self._running = False
        self._real_watchdog = 120.0
        # -- accounting (no-lost/double-frame invariants) --------------------
        self.sent = 0
        self.delivered = 0
        self.dropped = 0

    # -- time -----------------------------------------------------------------
    def now(self) -> float:
        """The virtual clock (passed as ``clock=`` into NodeCore/monitor)."""
        return self._now

    def _draw_latency(self) -> float:
        lo, hi = self.latency
        return self.rng.uniform(lo, hi)

    # -- topology -------------------------------------------------------------
    def add_node(self, name: str) -> SimNode:
        if name in self._nodes:
            raise ValueError(f"sim node {name!r} already exists")
        node = SimNode(self, name, monitor_timeout=self.monitor_timeout,
                       monitor_poll=self.monitor_poll)
        self._nodes[name] = node
        return node

    def node_by_address(self, address: str) -> SimNode:
        name = address.split("://", 1)[1] if "://" in address else address
        return self._nodes[name]

    def _disk(self, name: str) -> VirtualDisk:
        """The node's durable device (§11): keyed by *name*, not node
        object, so a restarted node replays the image its predecessor
        wrote. Re-opening un-halts a device parked by a crash."""
        d = self._disks.get(name)
        if d is None:
            d = self._disks[name] = VirtualDisk()
        else:
            d.halt = False
        return d

    def _register_transport(self, t: SimTransport) -> None:
        self._transports[(t.client_id, t.node.node_name)] = t

    def _transport_for(self, client_id: str,
                       node_name: str) -> Optional[SimTransport]:
        return self._transports.get((client_id, node_name))

    def set_affinity(self, client_id: str, address: str) -> None:
        """Declare a client process's locality group (a node address): it
        rides every dispense batch the client sends and feeds the home
        node's per-object affinity counters (§10 lease migration)."""
        self._affinity[client_id] = address
        for (cid, _n), t in self._transports.items():
            if cid == client_id:
                t.affinity = address

    # -- partitions (§10 split-brain exploration) ------------------------------
    def partition(self, a_nodes: List[str], b_nodes: List[str],
                  start: float, duration: float,
                  label: Optional[str] = None) -> None:
        """Cut the server-to-server links between node groups ``a`` and
        ``b`` during ``[start, start + duration)`` of virtual time. Client
        links stay up on BOTH sides — the split-brain scenario: clients
        keep talking to a primary that can no longer renew its lease while
        the other side promotes. Cut peer frames are dropped (one-ways
        silently; requests/replies fail the in-flight future, the TCP-RST
        analogue), all counted in ``dropped``."""
        cut = {"a": frozenset(a_nodes), "b": frozenset(b_nodes),
               "label": label or f"{'+'.join(a_nodes)}|{'+'.join(b_nodes)}"}
        self._push(start, "partition_on", cut)
        self._push(start + duration, "partition_off", cut)

    def _is_cut(self, sender: str, receiver: str) -> bool:
        for cut in self._partitions:
            if ((sender in cut["a"] and receiver in cut["b"])
                    or (sender in cut["b"] and receiver in cut["a"])):
                return True
        return False

    def client_registry(self, client_id: str) -> Registry:
        """A client-side :class:`Registry` for one simulated client
        *process*: one :class:`SimTransport` per node, federated bindings
        — the sim analogue of ``Registry.connect("host:port")``."""
        reg = Registry()
        for node in self._nodes.values():
            reg.connect(node.address,
                        client=SimTransport(self, node, client_id))
        return reg

    # -- fault injection ------------------------------------------------------
    def inject_crash(self, client_id: str, op: str, nth: int = 1,
                     phase: str = "after_send",
                     label: Optional[str] = None) -> None:
        """Crash-stop ``client_id`` at the ``nth`` send of ``op``
        (``before_send`` or ``after_send``)."""
        assert phase in ("before_send", "after_send"), phase
        self._injections.append({
            "client_id": client_id, "op": op, "nth": nth, "phase": phase,
            "label": label or f"{op}/{phase}#{nth}"})

    def crash_node_at(self, node_name: str, at: float) -> None:
        """Crash-stop a home node at virtual time ``at``."""
        self._push(at, "node_crash", node_name)

    def restart_node_at(self, node_name: str, at: float) -> None:
        """Restart a crashed home node at virtual time ``at`` under its
        old identity (§11): a fresh process replays the surviving disk
        image and runs the rejoin protocol against the live chains."""
        self._push(at, "node_restart", node_name)

    def inject_wal_crash(self, node_name: str, nth: int = 1,
                         label: Optional[str] = None) -> None:
        """Crash-stop a node at its ``nth`` WAL frame append, tearing
        that frame (the ``node-mid-wal-append`` label): the write itself
        is the crash point, so the frame can never land whole — replay
        must truncate it."""
        disk = self._disk(node_name)
        spec = {"node": node_name, "nth": nth, "n": 0, "fired": False,
                "label": label or f"{node_name}:node-mid-wal-append#{nth}"}

        def hook(d: VirtualDisk, spec: dict = spec) -> None:
            if not self._running:
                return      # setup binds don't count: fire mid-schedule
            spec["n"] += 1
            if spec["fired"] or spec["n"] != spec["nth"]:
                return
            spec["fired"] = True
            self.fired_injections.append(spec["label"])
            d.tear_tail(self.rng)
            d.halt = True
            # never raise mid-handler: the crash lands right after the
            # writer's synchronous slice, like an after_deliver injection
            self._push(self._now, "node_crash", spec["node"])

        disk.on_append = hook

    def inject_node_crash(self, node_name: str, op: str, nth: int = 1,
                          phase: str = "before_deliver",
                          label: Optional[str] = None) -> None:
        """Crash-stop a home node at the ``nth`` delivery of ``op`` to it
        (any sender — client or server-to-server peer link). With
        ``before_deliver`` the message is lost with the node (the caller's
        in-flight future fails, §3.4); with ``after_deliver`` the node
        crashes right after its handler's synchronous slice — i.e. after
        the op ran, or mid-op at its first blocking point. Targets the
        chained-commit / replication steps: ``commit_chain`` (coordinator),
        ``commit_wave`` (mid-wave), ``commit_decide`` (mid-decision-chain),
        ``repl_apply`` / ``repl_final`` (replica chain)."""
        assert phase in ("before_deliver", "after_deliver"), phase
        self._node_injections.append({
            "node": node_name, "op": op, "nth": nth, "phase": phase,
            "fired": False,
            "label": label or f"{node_name}:{op}/{phase}#{nth}"})

    def _check_node_injection(self, node: "SimNode", op: str) -> None:
        if not self._node_injections or not node.alive:
            return
        key = (node.node_name, op)
        self._node_op_counts[key] = n = self._node_op_counts.get(key, 0) + 1
        for spec in self._node_injections:
            if (spec["node"] == node.node_name and spec["op"] == op
                    and spec["nth"] == n and not spec["fired"]):
                spec["fired"] = True
                self.fired_injections.append(spec["label"])
                if spec["phase"] == "before_deliver":
                    self._do_node_crash(node.node_name)
                else:
                    # Fires after the delivering handler's synchronous
                    # slice (the scheduler pops it next).
                    self._push(self._now, "node_crash", node.node_name)

    def _check_injection(self, t: SimTransport, op: str, phase: str) -> None:
        if t.client_id.startswith("peer:") or not self._injections:
            return
        if phase == "before_send":
            # Count each client-visible send attempt once, at before_send.
            key = (t.client_id, op)
            self._op_counts[key] = self._op_counts.get(key, 0) + 1
        n = self._op_counts.get((t.client_id, op), 0)
        for spec in self._injections:
            if (spec["client_id"] == t.client_id and spec["op"] == op
                    and spec["phase"] == phase and spec["nth"] == n
                    and t.client_id not in self._crashed_clients):
                self._crash_client(t.client_id, spec["label"])
                raise SimCrash(f"{t.client_id} crashed at {spec['label']}")

    def _crash_client(self, client_id: str, label: str) -> None:
        self._crashed_clients[client_id] = label
        self.fired_injections.append(label)
        self._trace(f"crash {client_id} label={label}")
        transports = [t for (cid, _n), t in self._transports.items()
                      if cid == client_id]
        for t in transports:
            t._crash()
        # The presence signal: half the seeds drop the "connection"
        # promptly (instant detection), half go silent and leave it to the
        # heartbeat-timeout reaper — both §3.4 detection paths explored.
        if self.rng.random() < 0.5:
            for t in transports:
                self._send_raw(t, t.to_server, "vanish", None, None, None)
        for node in self._nodes.values():
            self._arm_reaper(node)

    def _do_node_crash(self, node_name: str) -> None:
        node = self._nodes.get(node_name)
        if node is None or not node.alive:
            return
        node.alive = False
        self._trace(f"node-crash {node_name}")
        disk = self._disks.get(node_name)
        if disk is not None:
            # settle unsynced WAL frames with ordered-device semantics
            # (seeded: a prefix lands, one frame may land torn)
            disk.crash(self.rng)
        for (cid, nname), t in list(self._transports.items()):
            if nname != node_name:
                continue
            dropped = len(t.to_server.queue) + len(t.to_client.queue)
            self.dropped += dropped
            t.to_server.queue.clear()
            t.to_client.queue.clear()
            t._mark_dead("node crashed")
        # Unwind handler actors parked inside the dead node: their waits
        # will never fire (the node's counters are gone with it).
        for entry in list(self._watchers):
            actor = entry[0]
            if (entry[2] and actor.kind == "handler"
                    and actor.node is node):
                entry[2] = False
                self._watchers.remove(entry)
                actor.poisoned = True
                self._resume(actor)

    def _do_node_restart(self, node_name: str) -> None:
        """§11 restart: a fresh SimNode under the old identity replays
        the surviving disk image (``SimNode.__init__`` builds its Wal
        over the same VirtualDisk) and rejoins its chains on a handler
        actor. Every transport keyed to the name is re-pointed at the
        reborn process and revived — the sim analogue of reconnecting to
        the same host:port."""
        old = self._nodes.get(node_name)
        if old is None or old.alive:
            return
        node = SimNode(self, node_name, monitor_timeout=self.monitor_timeout,
                       monitor_poll=self.monitor_poll)
        # The reborn process reads the same "config" the old one ran
        # with — a restarted node with a mismatched lease TTL would ack
        # renewals and compute promise windows on a different clock than
        # the rest of the deployment.
        node.leases.ttl = old.leases.ttl
        node.migrate_auto = old.migrate_auto
        self._nodes[node_name] = node
        self._trace(f"node-restart {node_name}")
        for (cid, nname), t in list(self._transports.items()):
            if nname != node_name:
                continue
            with t._lock:
                t.node = node
                t.alive = True
        if node._recovered is not None and node._recovered.objects:
            node._spawn_bg(node.rejoin_chains, name="rejoin")

    # -- sending --------------------------------------------------------------
    def _send(self, t: SimTransport, req_id: Optional[int], op: str,
              kwargs: dict, fut: Optional[SimFuture]) -> None:
        if not self._running:
            # Setup/teardown (topology binds, final state reads): execute
            # synchronously — these happen outside the simulated schedule.
            self._immediate(t, req_id, op, kwargs, fut)
            return
        self._send_raw(t, t.to_server, "req", req_id, op, (kwargs, fut))

    def _immediate(self, t: SimTransport, req_id: Optional[int], op: str,
                   kwargs: dict, fut: Optional[SimFuture]) -> None:
        op, kwargs = self._roundtrip((op, kwargs))
        if op in t.node._CONN_OPS:
            kwargs = dict(kwargs, _conn=t.conn)
        if req_id is None:
            t.node._handle_oneway(t.conn, op, kwargs)
            return
        try:
            value, status = t.node._dispatch(op, kwargs), OK
        except BaseException as e:  # noqa: BLE001 - serialize to peer
            status, value = ERR, encode_error(e)
        status, value = self._roundtrip((status, value))
        if fut is not None:
            if status == OK:
                fut.set_result(value)
            else:
                fut.set_error(value)

    def _send_reply(self, node: SimNode, t: SimTransport, req_id: int,
                    status: str, value: Any) -> None:
        self._send_raw(t, t.to_client, "reply", req_id, status, value)

    def _send_note(self, node: SimNode, t: SimTransport, note: dict) -> None:
        if not self._running:
            t._handle_note(self._roundtrip(note))
            return
        self._send_raw(t, t.to_client, "note", None, None, note)

    def _send_raw(self, t: SimTransport, link: _Link, kind: str,
                  req_id: Optional[int], a: Any, b: Any) -> None:
        if not self._running:
            raise RuntimeError("simnet is not running (setup uses call())")
        self.sent += 1
        at = max(self._now + self._draw_latency(), link.last_time)
        link.last_time = at
        link.queue.append((kind, req_id, a, b))
        self._push(at, "pump", (t, link))

    # -- event heap -----------------------------------------------------------
    def _push(self, at: float, kind: str, payload: Any) -> None:
        self._seq += 1
        heapq.heappush(self._events, (at, self._seq, kind, payload))

    # -- timers ---------------------------------------------------------------
    def _arm_heartbeat(self, t: SimTransport) -> None:
        if not t._hb_armed:
            t._hb_armed = True
            self._push(self._now + self.heartbeat_interval, "hb", t)

    def _arm_reaper(self, node: SimNode) -> None:
        if not node._reaper_armed and node.alive:
            node._reaper_armed = True
            self._push(self._now + node.monitor.poll_interval, "reaper", node)

    # -- actors ---------------------------------------------------------------
    def spawn(self, fn: Callable[[], None], name: str) -> _Actor:
        """Register a client actor; it starts running when :meth:`run`
        schedules it (all actors start at time 0, in spawn order)."""
        actor = _Actor(name, "client")

        def main() -> None:
            self._tl.actor = actor
            versioning.set_blocking_wait(self.wait_event)
            # Client-side spans of this virtual client land on its own
            # track and read the virtual clock (trace determinism).
            _txtrace.set_thread_tracer(
                _txtrace.tracer(f"client:{name}", clock=self.now))
            actor.sem.acquire()
            try:
                fn()
            except SimCrash:
                actor.crashed = True
            except BaseException as e:  # noqa: BLE001 - seed failure report
                actor.crashed = True
                self._trace(f"actor-error {name}: {type(e).__name__}: {e}")
                raise
            finally:
                actor.finished = True
                self._sched_sem.release()

        actor.thread = threading.Thread(target=main, name=f"sim-{name}",
                                        daemon=True)
        actor.thread.start()
        self._clients.append(actor)
        self._push(0.0, "start", actor)
        return actor

    def _spawn_handler(self, fn: Callable[[], None],
                       node: SimNode) -> None:
        if self._idle_handlers:
            actor = self._idle_handlers.pop()
        else:
            actor = _Actor(f"handler-{len(self._all_handlers)}", "handler")
            self._all_handlers.append(actor)

            def loop(a: _Actor = actor) -> None:
                self._tl.actor = a
                versioning.set_blocking_wait(self.wait_event)
                while True:
                    a.sem.acquire()
                    job = a.fn
                    if job is None:
                        return
                    # Pooled handler threads serve different nodes over
                    # time: bind the serving node's tracer per job so
                    # e.g. chained-dispense peer RPCs issued from here
                    # land on that node's track, on the virtual clock.
                    if _txtrace.enabled:
                        _txtrace.set_thread_tracer(
                            a.node.obs_tracer if a.node is not None
                            else None)
                    try:
                        job()
                    except SimCrash:
                        pass        # poisoned: node died under us
                    except BaseException as e:  # noqa: BLE001
                        self._trace(f"handler-error: "
                                    f"{type(e).__name__}: {e}")
                    a.fn = None
                    a.node = None
                    a.poisoned = False
                    self._idle_handlers.append(a)
                    self._sched_sem.release()

            actor.thread = threading.Thread(target=loop,
                                            name=f"sim-{actor.name}",
                                            daemon=True)
            actor.thread.start()
        actor.fn = fn
        actor.node = node
        actor.poisoned = False
        self._resume(actor)

    def _resume(self, actor: _Actor) -> None:
        """Hand the run token to ``actor``; returns when it yields, parks,
        or finishes. A real-time watchdog converts an un-hooked real block
        into a diagnosable failure instead of a silent hang."""
        actor.sem.release()
        if not self._sched_sem.acquire(timeout=self._real_watchdog):
            raise SimDeadlock(
                f"actor {actor.name} blocked on a real (un-hooked) "
                f"primitive for {self._real_watchdog}s", self.trace_text())

    def _yield_token(self, actor: _Actor) -> None:
        self._sched_sem.release()
        actor.sem.acquire()

    # -- blocking points ------------------------------------------------------
    def wait_event(self, ev: threading.Event,
                   timeout: Optional[float] = None) -> bool:
        """The simulation's universal blocking wait (installed as the
        versioning wait hook, used by futures, joins, and sleeps): park
        this actor until ``ev`` is set or ``timeout`` virtual seconds
        pass. Returns ``ev.is_set()``."""
        actor = getattr(self._tl, "actor", None)
        if actor is None:
            # Not inside the simulation (setup/teardown code): native wait.
            return ev.wait(timeout if timeout is not None else 5.0)
        if ev.is_set():
            return True
        entry = [actor, ev, True]
        self._watchers.append(entry)
        if timeout is not None:
            self._push(self._now + timeout, "timeout", entry)
        self._yield_token(actor)
        if actor.poisoned:
            raise SimCrash(f"node died under {actor.name}")
        return ev.is_set()

    def sleep(self, dt: float) -> None:
        """Advance this actor by ``dt`` virtual seconds."""
        self.wait_event(threading.Event(), dt)

    # -- scheduler ------------------------------------------------------------
    def run(self, max_virtual: float = 600.0) -> None:
        """Run the simulation to quiescence: all client actors finished
        and every queued event drained."""
        self._running = True
        try:
            while True:
                if self._wake_ready_watcher():
                    continue
                if not self._events:
                    if all(a.finished for a in self._clients):
                        return
                    self._deadlock("no runnable actor and no pending event")
                t, _seq, kind, payload = heapq.heappop(self._events)
                if t > max_virtual:
                    self._deadlock(f"virtual time cap {max_virtual}s hit")
                self._now = max(self._now, t)
                self._execute(kind, payload)
        finally:
            self._running = False

    def _wake_ready_watcher(self) -> bool:
        for entry in self._watchers:
            actor, ev, active = entry
            if active and ev.is_set():
                entry[2] = False
                self._watchers.remove(entry)
                self._resume(actor)
                return True
        return False

    def _deadlock(self, why: str) -> None:
        parked = [e[0].name for e in self._watchers if e[2]]
        raise SimDeadlock(
            f"simnet seed={self.seed} wedged ({why}); parked={parked}",
            self.trace_text())

    def _execute(self, kind: str, payload: Any) -> None:
        if kind == "start":
            self._trace(f"start {payload.name}")
            self._resume(payload)
        elif kind == "pump":
            self._pump(*payload)
        elif kind == "timeout":
            actor, _ev, active = payload
            if active:
                payload[2] = False
                try:
                    self._watchers.remove(payload)
                except ValueError:
                    pass
                self._resume(actor)
        elif kind == "hb":
            self._fire_heartbeat(payload)
        elif kind == "reaper":
            self._fire_reaper(payload)
        elif kind == "node_crash":
            self._do_node_crash(payload)
        elif kind == "node_restart":
            self._do_node_restart(payload)
        elif kind == "partition_on":
            self._partitions.append(payload)
            self._trace(f"partition-on {payload['label']}")
        elif kind == "partition_off":
            if payload in self._partitions:
                self._partitions.remove(payload)
                self._trace(f"partition-off {payload['label']}")
        elif kind == "unlock":
            t, link = payload
            link.locked = False
            if link.deferred > 0 and link.queue:
                link.deferred -= 1
                self._push(self._now, "pump", (t, link))
        else:  # pragma: no cover
            raise AssertionError(f"unknown event {kind!r}")

    # -- delivery -------------------------------------------------------------
    def _pump(self, t: SimTransport, link: _Link) -> None:
        if link.locked:
            # This pump's message cannot start until the in-flight one-way
            # completes (TCP's inline-FIFO guarantee); the unlock re-pumps.
            link.deferred += 1
            return
        if not link.queue:
            return
        kind, req_id, a, b = link.queue.pop(0)
        if link is t.to_server:
            self._deliver_to_server(t, link, kind, req_id, a, b)
        else:
            self._deliver_to_client(t, kind, req_id, a, b)
        # Chain deliveries whose pump events fired while the link was
        # locked (their scheduled times have already passed).
        if not link.locked and link.deferred > 0 and link.queue:
            link.deferred -= 1
            self._push(self._now, "pump", (t, link))

    def _roundtrip(self, obj: Any) -> Any:
        """State isolation between simulated processes: every payload is
        pickled across the 'wire', exactly like TCP framing would."""
        return pickle.loads(pickle.dumps(obj))

    def _deliver_to_server(self, t: SimTransport, link: _Link, kind: str,
                           req_id: Optional[int], a: Any, b: Any) -> None:
        node = t.node
        if kind == "vanish":
            self._trace(f"deliver {t.client_id}->{node.node_name} vanish")
            self.delivered += 1
            node._client_vanished(t.client_id)
            return
        op, (kwargs, fut) = a, b
        if (self._partitions and t.client_id.startswith("peer:")
                and self._is_cut(t.client_id[5:], node.node_name)):
            # A cut peer link: the frame is lost. One-ways go silently
            # (lease renewals starve — that is the point); a request's
            # sender learns promptly (the TCP-RST analogue), so no actor
            # is stranded awaiting a reply that can never come.
            self._trace(f"drop {t.client_id}->{node.node_name} "
                        f"{self._msg_label(req_id, op, kwargs)} (partition)")
            self.dropped += 1
            if fut is not None and not fut.done():
                fut.set_error(RemoteObjectFailure(
                    f"link {t.client_id}->{node.address} partitioned with "
                    f"{op!r} in flight"))
            return
        self._check_node_injection(node, op)
        if not node.alive:
            self._trace(f"drop {t.client_id}->{node.node_name} "
                        f"{self._msg_label(req_id, op, kwargs)} (node dead)")
            self.dropped += 1
            if fut is not None and not fut.done():
                # The node died while this request was in flight: its
                # reply will never come — fail the caller (§3.4), exactly
                # like the TCP client's _mark_dead does for in-flight
                # futures on a broken connection.
                fut.set_error(RemoteObjectFailure(
                    f"node server {node.address} crash-stopped with "
                    f"{op!r} in flight"))
            return
        self.delivered += 1
        self._trace(f"deliver {t.client_id}->{node.node_name} "
                    f"{self._msg_label(req_id, op, kwargs)}")
        try:
            op, kwargs = self._roundtrip((op, kwargs))
        except Exception as e:  # noqa: BLE001 - unpicklable argument
            if req_id is not None:
                self._send_reply(node, t, req_id, ERR, encode_error(e))
            return
        if op in node._CONN_OPS:
            kwargs = dict(kwargs, _conn=t.conn)
        if req_id is None:
            # One-way: completes before any later message on this link
            # starts (the TCP reader's inline-FIFO guarantee).
            link.locked = True

            def oneway_job() -> None:
                try:
                    node._handle_oneway(t.conn, op, kwargs)
                finally:
                    self._push(self._now, "unlock", (t, link))

            self._spawn_handler(oneway_job, node)
            return

        def request_job() -> None:
            try:
                value, status = node._dispatch(op, kwargs), OK
            except SimCrash:
                raise
            except BaseException as e:  # noqa: BLE001 - serialize to peer
                status, value = ERR, encode_error(e)
            if node.alive:
                self._send_reply(node, t, req_id, status, value)

        self._spawn_handler(request_job, node)

    def _deliver_to_client(self, t: SimTransport, kind: str,
                           req_id: Optional[int], a: Any, b: Any) -> None:
        node = t.node
        if t.crashed:
            self._trace(f"drop {node.node_name}->{t.client_id} "
                        f"{kind}#{req_id} (client crashed)")
            self.dropped += 1
            return
        if (self._partitions and t.client_id.startswith("peer:")
                and self._is_cut(node.node_name, t.client_id[5:])):
            self._trace(f"drop {node.node_name}->{t.client_id} "
                        f"{kind}#{req_id} (partition)")
            self.dropped += 1
            if kind == "reply":
                with t._lock:
                    fut = t._pending.pop(req_id, None)
                if fut is not None and not fut.abandoned:
                    fut.set_error(RemoteObjectFailure(
                        f"link {node.address}->{t.client_id} partitioned "
                        f"with reply#{req_id} in flight"))
            return
        self.delivered += 1
        if kind == "reply":
            status, value = a, b
            self._trace(f"deliver {node.node_name}->{t.client_id} "
                        f"reply#{req_id} {status}")
            try:
                status, value = self._roundtrip((status, value))
            except Exception as e:  # noqa: BLE001
                status, value = ERR, RuntimeError(f"undecodable reply: {e}")
            t._deliver_reply(req_id, status, value)
        else:   # note
            note = b
            self._trace(f"deliver {node.node_name}->{t.client_id} "
                        f"note {note.get('kind')} "
                        f"txn={self._txn_label(note.get('txn'))} "
                        f"obj={note.get('name')}")
            try:
                note = self._roundtrip(note)
            except Exception:  # noqa: BLE001 - like a corrupt push: drop
                return
            t._handle_note(note)

    # -- timers ---------------------------------------------------------------
    def _fire_heartbeat(self, t: SimTransport) -> None:
        if t.crashed or not t.alive:
            t._hb_armed = False
            return
        with t._lock:
            txns = sorted(t._active_txns)
        if not txns:
            t._hb_armed = False
            return
        self.n_heartbeats = getattr(self, "n_heartbeats", 0) + 1
        self._send_raw(t, t.to_server, "req", None, "heartbeat",
                       ({"client_id": t.client_id, "txns": txns}, None))
        self._push(self._now + self.heartbeat_interval, "hb", t)

    def _fire_reaper(self, node: SimNode) -> None:
        if not node.alive:
            node._reaper_armed = False
            return
        if node.reap_stale(self._now):     # sessions remain: keep polling
            self._push(self._now + node.monitor.poll_interval, "reaper",
                       node)
        else:
            node._reaper_armed = False

    # -- trace ----------------------------------------------------------------
    def _txn_label(self, uid: Optional[str]) -> str:
        """Normalize transaction uids (which embed process-global counters)
        to first-appearance labels, so traces replay byte-identically."""
        if uid is None:
            return "-"
        label = self._txn_labels.get(uid)
        if label is None:
            label = f"T{len(self._txn_labels) + 1}"
            self._txn_labels[uid] = label
        return label

    def _msg_label(self, req_id: Optional[int], op: str,
                   kwargs: dict) -> str:
        tag = f"req#{req_id}" if req_id is not None else "oneway"
        parts = [tag, op]
        txn = kwargs.get("txn")
        if txn is not None:
            parts.append(f"txn={self._txn_label(txn)}")
        name = kwargs.get("name")
        if name is not None:
            parts.append(f"obj={name}")
        names = kwargs.get("names")
        if names:
            parts.append(f"objs={','.join(names)}")
        return " ".join(parts)

    def _trace(self, line: str) -> None:
        self._trace_lines.append(f"{self._now:.6f} {line}")

    def trace_text(self) -> str:
        """The replayable schedule: every delivery, timer, crash, and
        expiry decision the scheduler made, in order, in virtual time.
        Byte-identical across runs of the same seed."""
        return "\n".join(self._trace_lines) + "\n"

    # -- inspection / teardown ------------------------------------------------
    def converged(self) -> List[str]:
        """Names of shared objects whose version chain did NOT converge
        to quiescence (``gv == lv == ltv``) — leaked/wedged versions, the
        §3.4 rollback-to-oldest invariant. Empty means all clean. Dead
        nodes are skipped (their objects left the system)."""
        bad = []
        for node in self._nodes.values():
            if not node.alive:
                continue
            for name, shared in node.registry.all_objects().items():
                h = shared.header
                if not (h.gv == h.lv == h.ltv):
                    bad.append(f"{name}: gv={h.gv} lv={h.lv} ltv={h.ltv}")
        return bad

    def shutdown(self) -> None:
        for actor in self._all_handlers:
            actor.fn = None
            actor.sem.release()
        for node in self._nodes.values():
            node.registry.shutdown()


def build_simnet(seed: int, n_nodes: int, **kw: Any) -> SimNet:
    """A SimNet with ``n_nodes`` nodes named ``node0..node{n-1}``."""
    net = SimNet(seed, **kw)
    for i in range(n_nodes):
        net.add_node(f"node{i}")
    return net
