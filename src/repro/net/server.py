"""The node server process (DESIGN.md §3.1).

Hosts a :class:`~repro.core.registry.Registry` with one
:class:`~repro.core.registry.Node` — the real OS-process realization of the
paper's remote host: the ``SharedObject``s, their ``VersionHeader``s, the
per-node :class:`~repro.core.executor.Executor`, and the §3.4
:class:`~repro.core.faults.TransactionMonitor` all live here.

**Delegation boundary.** For every client transaction the server keeps a
*session* holding the home-node halves of the client's ``ObjectAccess``
records. Since PR 3 those halves *are* ``ObjectAccess`` subclasses
(:class:`_ServerAccess`): checkpointing, buffering, log application
(through :class:`~repro.core.buffers.LogBuffer`), release, rollback, and
termination run the same base methods the in-process transport runs —
the wire handlers only marshal arguments. The §2.7/§2.8.4 task bodies are
overridden to add the §3.4 expiry checks a multi-process world needs, but
delegate the actual log replay to ``LogBuffer.apply_to``.

**Multiplexed connections.** One framed socket per client process carries
tagged requests, one-way messages, replies, and server pushes
(``wire.py``). The per-connection reader handles quick operations inline
and hands potentially-blocking ones (gate waits, dispensing, task joins,
service-time-bearing method calls) to a thread each, so a parked RPC never
stalls the link — replies complete out of order, matched by request id.
One-way messages are always processed inline, which gives them FIFO
ordering relative to later requests on the same connection (a pipelined
kickoff is guaranteed to be registered before the join that follows it,
and a deferred-ack trailing write is guaranteed to be applied before any
later synchronous operation observes the object); their failures are
pushed back as ``oneway_err`` notes (error deferral). Operation fusion
(DESIGN.md §3.1 v3) executes client-visible *runs* server-side:
``txn_call_batch`` (and the ``tail=`` batch of ``open_call``) runs a
FIFO-atomic call sequence against one held access with an error-index
reply — prefix applied, suffix never executed.
When a §2.7/§2.8.4 task completes, a ``task_done`` note — carrying the
read buffer's state when small (piggyback read protocol) — is pushed on
the owning client's connection(s).

**Version-lock service.** ``dispense_batch`` implements the server side of
start-time global-order version acquisition (§2.10.2): it acquires this
node's per-object dispensing gates in header-uid order, dispenses private
versions for the whole per-node batch, and *holds* the gates until the
client's ``release_version_locks`` (2PL on version locks across nodes —
one round-trip per node, not per object). Gates are plain ``Lock``s, not
the header ``RLock``s, because they must be releasable from a different
connection thread; dispensing itself still happens under the header lock.

**Failure detection (§3.4).** Sessions are refreshed by client heartbeats
(one-way messages riding the mux link); a client process that dies stops
heartbeating (session reaper, detector timeout) and — faster — its mux
connection drops (immediate: the connection doubles as the presence
signal). Either way ``_expire_session`` performs the paper's self-rollback
for everything the session dispensed on: restore the checkpoint where
state was modified (oldest-restore-wins on the instance epoch), bump the
epoch so readers of the dead transaction's state cascade-abort, and
advance ``lv``/``ltv`` past its private version so survivors' chains
unwedge, then commit. Dead clients' held version-lock gates are
force-released the same way. The object-level :class:`TransactionMonitor`
still runs for in-process users of an embedded server's registry.

Run standalone::

    python -m repro.net.server --name node0 --port 0 --announce

which prints ``LISTENING host:port`` on stdout for the parent to parse
(:mod:`repro.net.spawn` automates this).
"""
from __future__ import annotations

import argparse
import logging
import os
import pickle
import queue
import socket
import sys
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.api import (INF, InstanceInvalidated, Mode,
                            RemoteObjectFailure, Suprema,
                            TransactionError, commute_classes, method_mode)
from repro.core.buffers import CopyBuffer
from repro.core.executor import Task, defer_wake_inline
from repro.core.faults import TransactionMonitor
from repro.core.registry import Registry, SharedObject
from repro.core.transaction import ObjectAccess
from repro.core.versioning import blocking_wait, skip_version, wait_quiescent

from repro.obs import metrics as _metrics
from repro.obs import txtrace as _txtrace

from .leases import LeaseManager, LeaseRearming, ObjectMovedError
from .replication import ReplicaRecord, ReplicationManager
from .wal import FileStorage, Wal, fold_payload
from .wire import (ConnectionClosed, ERR, FrameReader, NOTE, OK,
                   PIGGYBACK_MAX, WireError, encode_error,
                   frame as wire_frame, oob, send_frames, send_msg)

log = logging.getLogger("repro.net.server")

_SERVER_SUP = Suprema(reads=INF, writes=INF, updates=INF)

#: Auto-migration trigger (§10): a remote affinity group must cast at
#: least this many votes on an object AND lead every other group 2:1
#: before a lease handoff is queued — hysteresis against ping-ponging a
#: hot object between two balanced accessors.
MIGRATE_THRESHOLD = 8


class _WouldBlock(Exception):
    """A non-blocking fast-path attempt hit contention: redo on the pool."""


class _Conn:
    """Per-connection send state: one lock serializes the socket's write
    side across worker threads, pushes, and reply piggybacks.
    ``pending_out`` holds the unsent tail of a partially written push
    frame — it MUST go out before any other frame on this socket."""

    __slots__ = ("sock", "send_lock", "notes", "pending_out", "client_id")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.send_lock = threading.Lock()
        self.notes: List[dict] = []          # pending piggyback notes
        self.pending_out = b""               # spilled partial push frame
        self.client_id: Optional[str] = None  # set by mux_hello


class _WorkerPool:
    """Grow-on-demand thread pool with idle-worker reuse.

    Potentially-blocking RPCs need a thread each (a capped pool would
    deadlock: gate-wait RPCs could occupy every worker while the release
    that frees them queues behind), but spawning a fresh thread per request
    costs real latency on the hot path — so idle workers are reused and the
    pool only grows when every worker is busy."""

    def __init__(self, name: str = "op"):
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._lock = threading.Lock()
        self._idle = 0
        self._spawned = 0
        self._name = name

    def submit(self, fn: Callable[[], None]) -> None:
        with self._lock:
            grow = self._idle == 0
            if grow:
                self._spawned += 1
                n = self._spawned
            else:
                self._idle -= 1
        if grow:
            threading.Thread(target=self._run, name=f"{self._name}-{n}",
                             daemon=True).start()
        self._q.put(fn)

    def _run(self) -> None:
        while True:
            fn = self._q.get()
            if fn is None:
                return
            try:
                fn()
            except Exception:  # noqa: BLE001 - handlers report their own errors
                pass
            with self._lock:
                self._idle += 1

    def stop(self) -> None:
        with self._lock:
            n = self._spawned
        for _ in range(n):
            self._q.put(None)


class _ServerAccess(ObjectAccess):
    """Home-node half of one transaction's ``ObjectAccess`` record.

    A real :class:`ObjectAccess` whose owning "transaction" is the server
    session — checkpoint/rollback/buffer/log logic lives once, on the base
    class (ROADMAP item from the PR 2 review). Only the §2.7/§2.8.4 task
    bodies are overridden: in a multi-process world they must no-op after a
    §3.4 expiry (a dead client's log must never be applied), which needs
    the expiry check and the apply to share the header lock.
    """

    __slots__ = ("server", "push_conn", "task_result", "push_done",
                 "inline_tasks", "ship_state", "aborted", "repl_origin",
                 "repl_done", "oneway_entries")

    def __init__(self, server: "NodeServer", session: "_Session",
                 shared: SharedObject, pv: int):
        super().__init__(session, shared, _SERVER_SUP)
        self.pv = pv
        self.server = server
        #: connection to push the task-done note to; ``None`` while a
        #: carrier RPC (the dispense reply) may still deliver it instead.
        self.push_conn: Optional[_Conn] = None
        self.task_result: Optional[tuple] = None  # (error, buf payload)
        self.push_done = False
        #: ship held-state copies to the client while it holds access?
        #: flips off permanently once the state proves too big/unpicklable.
        self.ship_state = True
        #: set (under the header lock) by the abort path: a stale commit
        #: wave that wakes afterwards must not apply this access's log.
        self.aborted = False
        #: coordinator address of the commit wave currently prepping this
        #: access (chained commit): shipped with tentative replication so
        #: a promoting follower knows whom to ask for the decision.
        self.repl_origin: Optional[str] = None
        #: tentative replication already shipped (commit_prep ran): the
        #: release below it may skip the pin-state snapshot.
        self.repl_done = False
        #: True while the spawner runs on a worker thread (dispense): an
        #: open-gated task may run inline there, completing within the RPC
        #: so its result rides the reply. False from the conn reader (a
        #: one-way kickoff), where inline work would stall the link.
        self.inline_tasks = False
        #: §12 commute deltas shipped ahead of commit as ``commute_delta``
        #: one-ways. They precede whatever entries ride the commit RPC
        #: (same FIFO connection), so :meth:`absorb_entries` prepends them.
        self.oneway_entries: List[tuple] = []

    @property
    def session(self) -> "_Session":
        return self.txn

    def open_access(self, kind: str, timeout: Optional[float]) -> bool:
        """§2.8.2 open with a §3.4 expiry re-check after the gate wait.

        A crashed client's in-flight open parks on the access gate; the
        expiry's own chain-order ``skip_version`` is then exactly what
        opens that gate — without this check the woken handler would
        apply a dead transaction's operation to live state *after* the
        self-rollback ran, leaving it applied-unrestored (found by the
        simnet seed sweep). Checked under the header lock, which the
        expiry also holds while deciding what to restore."""
        blocked = super().open_access(kind, timeout)
        with self.shared.header.lock:
            if self.session.expired or self.aborted:
                raise InstanceInvalidated(
                    f"transaction {self.session.txn_uid!r} was rolled back "
                    f"while waiting to open {self.shared.name!r} "
                    f"(§3.4 crash-stop)")
        return blocked

    def _ro_buffer_code(self) -> None:
        if self.session.expired:
            return        # §3.4: the expiry advanced our version already
        super()._ro_buffer_code()

    def _lw_apply_code(self) -> None:
        # Full override (no super() call): re-wrap with the obs span the
        # base class would have emitted.
        if _txtrace.enabled:
            t0 = self._obs_tracer().now()
            self._lw_apply_server()
            self._obs_span("lw_apply", t0, detail=self.shared.name)
        else:
            self._lw_apply_server()

    def _lw_apply_server(self) -> None:
        shared = self.shared
        # The expired check and the apply happen under the header lock,
        # which _expire_session also takes before deciding whether to
        # restore: either we see the expiry and no-op, or the expiry sees
        # our checkpoint (self.st) and restores it — a dead transaction's
        # log can never slip through unrestored.
        with shared.header.lock:
            if self.session.expired:
                return    # §3.4: never apply a dead transaction's log
            inst = shared.header.instance
            st = CopyBuffer(shared.holder.obj, inst, home_node=shared.node)
            self.log.apply_to(shared.holder.obj)
            buf = CopyBuffer(shared.holder.obj, inst, home_node=shared.node)
            with self.lock:
                self.seen_instance = inst
                self.st = st
                self.buf = buf
                self.modified = True
                self.holds_access = True
        shared.header.release_to(self.pv)
        with self.lock:
            self.released = True

    def commit_prep(self) -> None:
        """Commit step 3 under the header lock, guarded against a stale
        wave: a ``commit_wave1`` worker that was parked in its commit
        condition while the client aborted (rollback + terminate + session
        end) must never apply the dead transaction's log afterwards. The
        abort marks ``aborted`` under the same header lock that serializes
        the checkpoint/apply here, so exactly one of the two orders holds:
        prep-then-rollback (the restore erases the applied log — the
        checkpoint was taken first) or rollback-then-prep (raises)."""
        with self.shared.header.lock:
            if self.aborted or self.session.expired:
                raise InstanceInvalidated(
                    f"access on {self.shared.name!r} was rolled back "
                    f"before commit step 3 could run")
            self.ensure_checkpoint()
            self.apply_log()
            if self.modified:
                # Tentative replication. The payload must be THIS
                # transaction's resulting state: if the object was
                # early-released (§2.7/§2.8.3-4 last use), successors have
                # already executed against live state, so ship the
                # release-time snapshot (``buf``); otherwise the header
                # lock still excludes successors and live state is ours.
                with self.lock:
                    snap = self.buf if self.released else None
                state = (snap.state if snap is not None
                         else self.shared.holder.obj)
                self.server.replication.on_commit_prep(
                    self.session.txn_uid, self.shared.name,
                    state, self.pv, self.repl_origin)
                self.repl_done = True
        # Release outside the lock: it wakes successors (possibly running
        # their tasks on this thread) and must not do so under our hold.
        self.release()

    def release(self) -> None:
        """Early release must pin this transaction's resulting state
        first: once successors run, live state is no longer ours, and the
        commit-time tentative replication (:meth:`commit_prep`) needs the
        release-time snapshot. The §2.7/§2.8.4 task bodies and
        ``snap_release`` already buffer before releasing; this covers the
        plain ``release`` one-way a client with a live held-state copy
        sends for a modified object."""
        if (not self.released and self.modified and not self.aborted
                and not self.repl_done and self.buf is None
                and self.server.replication.followers_of(self.shared.name)):
            self.snapshot_buf()
        super().release()

    def mark_aborted(self) -> None:
        with self.shared.header.lock:
            self.aborted = True

    def absorb_entries(self, entries: list) -> None:
        """Install the commit-shipped write log, preceded by any deltas
        that already arrived as ``commute_delta`` one-ways (client-issue
        order == wire order on the FIFO connection == this concatenation)."""
        if self.oneway_entries:
            merged = list(self.oneway_entries)
            self.oneway_entries = []
            merged.extend(entries)
            self.log.entries = merged
        elif entries:
            self.log.entries = list(entries)

    def _owner_label(self) -> str:
        return self.session.txn_uid

    def _obs_uid(self) -> str:
        # Full wire uid ("<client_id>#<id>[r<inc>]"): the export merges it
        # with client-side spans by the "#..." tail.
        return self.session.txn_uid

    def _submit_task(self, label: str, kind: str,
                     code: Callable[[], None]) -> Task:
        """Submit off the reader thread (``inline_ready=False`` — running
        the snapshot inline would stall every conversation on the socket)
        and deliver a ``task_done`` note to the client on completion.

        Delivery handshake (race-free under ``self.lock``): the completed
        task records its result; if ``push_conn`` is set it pushes, and if
        not — a carrier RPC (the dispense reply that spawned it) is still
        in flight and will piggyback the result instead. Whichever side
        runs second delivers."""
        server, session, name = self.server, self.session, self.shared.name

        def wrapped() -> None:
            error: Optional[BaseException] = None
            try:
                code()
            except BaseException as e:  # noqa: BLE001 - note + re-raise
                error = e
                raise
            finally:
                payload = (server._buf_payload(self)
                           if error is None else None)
                with self.lock:
                    self.task_result = (
                        encode_error(error) if error is not None else None,
                        payload)
                    conn = self.push_conn
                    if conn is not None:
                        self.push_done = True
                if conn is not None:
                    server._push_task_done(session, name, conn,
                                           self.task_result)

        # wake_inline: when a release opens this task's gate, it runs on
        # the releasing thread (trampolined) — the snapshot/apply and its
        # completion push cost the client exactly one wakeup.
        return self.shared.node.executor.submit(
            self.shared.header, kind, self.pv, wrapped,
            name=f"{label}:{name}:{self._owner_label()}",
            inline_ready=self.inline_tasks, wake_inline=True)


class _ServerCommuteAccess(_ServerAccess):
    """Home-node access record for a commute-group member (DESIGN.md §12).

    The server-side mirror of :class:`repro.core.transaction.CommuteAccess`
    in its group-active state — it only ever EXISTS while active; when
    :meth:`NodeCore._make_access` cannot join the group it builds a plain
    :class:`_ServerAccess` instead, and the client-shipped deltas become
    ordinary §2.8.4 log entries (the fallback is invisible to the client:
    commute methods are pure writes returning ``None`` either way).

    While the group lives, its deltas touch nothing locally: no
    checkpoint, no ``lv`` advance at release — the fold happens at
    *terminate*, strictly after the commit decision, under the object's
    per-class merge lock. Replication is the one thing that must NOT wait
    for the decision: ``commit_prep`` ships the member's entry list as a
    DELTA tentative (step 3, before the wave reply), so the §8
    tentative-before-decision invariant covers commute commits; the
    follower folds the delta into its committed snapshot when the final
    (or the decision) resolves it. All of a group's tentatives share
    ``seq == cg_pv``, which is why the follower's apply guard accepts
    equal sequence numbers (deltas fold — resolution order across members
    is free, because the method class commutes).
    """

    __slots__ = ("commute_cls", "_cg_left")

    def __init__(self, server: "NodeServer", session: "_Session",
                 shared: SharedObject, pv: int, commute_cls: str):
        super().__init__(server, session, shared, pv)
        self.commute_cls = commute_cls
        self._cg_left = False

    def commute_depart(self) -> None:
        """Leave the commute group exactly once. ``commute_leave``
        decrements the member count (NOT idempotent), and terminate, the
        §3.4 expiry, and a dispense-time expired re-check can race on the
        same access — the flag (under ``self.lock``) picks one winner."""
        with self.lock:
            if self._cg_left:
                return
            self._cg_left = True
        self.shared.header.commute_leave()

    # No state was touched before the fold: nothing to checkpoint,
    # validate, restore, or early-release.
    def ensure_checkpoint(self) -> None:
        pass

    def wait_termination(self, timeout: Optional[float]) -> bool:
        return False   # ltv == cg_pv - 1 by construction: never blocks

    def valid_commit(self) -> bool:
        return True

    def commit_prep(self) -> None:
        # Staleness check + DELTA tentative replication. The fold itself
        # must wait for the commit DECISION (terminate) — prepping applies
        # nothing locally — but the deltas ship to the followers NOW,
        # before the wave reply that feeds the decision. Without this the
        # §8 invariant (every tentative is at the followers before any
        # decision exists) would not cover commute commits: a primary
        # crashing between decision and fold would take the only copy of
        # the deltas with it while the promoted follower acks the decide.
        with self.shared.header.lock:
            if self.aborted or self.session.expired:
                raise InstanceInvalidated(
                    f"commute access on {self.shared.name!r} was rolled "
                    f"back before commit could run")
            with self.lock:
                entries = self.oneway_entries + self.log.entries
            if entries:
                self.server.replication.on_commute_prep(
                    self.session.txn_uid, self.shared.name, entries,
                    self.pv, self.repl_origin)
                with self.lock:
                    self.repl_done = True

    def release(self) -> None:
        # An lv advance would open exact successors' gates before the
        # group's folds landed — release rides the dissolve instead.
        with self.lock:
            self.released = True

    def rollback(self) -> None:
        self.mark_aborted()
        with self.lock:
            self.log.entries.clear()
            self.oneway_entries = []

    def terminate(self) -> None:
        if self.terminated:
            return
        self.terminated = True
        shared, session = self.shared, self.session
        with self.lock:
            # Capture the delta list under the access lock: a racing §3.4
            # expiry clears these same lists, and the fold below iterates
            # outside this lock.
            entries = self.oneway_entries + self.log.entries
            self.oneway_entries = []
            self.log.entries = []
            fold = (bool(entries) and not self.aborted
                    and not session.expired)
        if fold:
            h = shared.header
            with h.commute_merge_lock(self.commute_cls):
                obj = shared.holder.obj
                for method, args, kwargs in entries:
                    getattr(obj, method)(*args, **(kwargs or {}))
                with self.lock:
                    self.modified = True
                self.server.n_merged_deltas += len(entries)
            # Replication already happened: the DELTA tentative shipped at
            # commit_prep (step 3, before the decision), and the final
            # rides the caller's ``on_terminate`` right after this returns
            # — the follower folds its buffered copy of the same entries
            # then (or already did, if the decision broadcast beat us).
        shared.clear_holder(session)
        self.commute_depart()


class _Session:
    """All server-side state of one client transaction (its txn record).

    Duck-types the transaction for the monitor and for the base
    ``ObjectAccess`` methods: ``_accesses`` maps shared object → access
    record exactly like ``Transaction._accesses``, and the session is what
    ``shared.touch``/``clear_holder`` see as the holding transaction.
    """

    client_node = None      # ObjectAccess.raw_call's from_node

    def __init__(self, txn_uid: str, client_id: str,
                 now: Optional[float] = None):
        self.txn_uid = txn_uid
        self.client_id = client_id
        self._accesses: Dict[SharedObject, _ServerAccess] = {}
        self.tasks: Dict[str, Task] = {}     # object name -> release task
        self.held_gates: List[threading.Lock] = []
        self.last_contact = time.monotonic() if now is None else now
        self.expired = False      # set by §3.4 expiry; parked tasks no-op
        self.lock = threading.Lock()

    @property
    def id(self) -> str:
        return self.txn_uid

    def __repr__(self) -> str:  # pragma: no cover
        return f"_Session({self.txn_uid})"


class NodeCore:
    """Transport-independent node engine: sessions, op dispatch, §3.4.

    Everything a home node *is* — the registry node with its
    ``SharedObject``s and executor, the per-transaction sessions holding
    :class:`_ServerAccess` records, the version-lock dispensing gates, the
    full ``_op_*`` protocol surface, and the §3.4 crash-stop expiry — lives
    here, with NO knowledge of sockets, frames, threads-per-connection, or
    real time. Concrete transports subclass it:

    * :class:`NodeServer` adds the TCP machinery (listener, multiplexed
      connections, worker pool, pusher, real-time reaper);
    * :class:`repro.net.simnet.SimNode` delivers messages directly under a
      seeded virtual-time scheduler.

    The transport boundary is a handful of hooks:

    * ``_clock()``            — time source for the failure detector
      (real monotonic vs. the simulation's virtual clock);
    * ``_gate_acquire(gate)`` — how a dispense gate blocks (a real
      ``Lock.acquire`` vs. a virtual-time backoff loop);
    * ``_queue_note(conn, note)`` — how a server push reaches the client;
    * ``_push_target(conn, client_id)`` — which "connection" a task
      completion note should ride;
    * ``_peer(address)``      — the server-to-server transport for
      chained dispensing (§2.10.2);
    * ``_oob(payload)``       — wire-v3 out-of-band marking (identity off
      the TCP wire);
    * ``INLINE_KICKOFF_TASKS`` — whether §2.7/§2.8.4 kickoff tasks whose
      gate is already open run on the delivering thread (the simulation
      needs this for determinism; the TCP reader must not stall).
    """

    #: Ops whose handler needs the originating connection (to route task
    #: completion pushes back the way the kickoff came).
    _CONN_OPS = frozenset({"ro_buffer", "lw_apply", "dispense_batch"})

    #: §2.7/§2.8.4 kickoff tasks with an open gate: run on the delivering
    #: thread (True) or strictly asynchronously on the executor (False)?
    INLINE_KICKOFF_TASKS = False

    def __init__(self, node_name: str = "node0", *,
                 registry: Optional[Registry] = None,
                 monitor_timeout: float = 2.0, monitor_poll: float = 0.05,
                 executor_workers: int = 1,
                 clock: Callable[[], float] = time.monotonic,
                 wal: Optional[Wal] = None):
        self.registry = registry if registry is not None else Registry()
        self.node_name = node_name
        self._clock = clock
        try:
            self.node = self.registry.node(node_name)
        except KeyError:
            self.node = self.registry.add_node(
                node_name, executor_workers=executor_workers)
        self.monitor = TransactionMonitor(
            self.registry, timeout=monitor_timeout, poll_interval=monitor_poll,
            clock=clock)
        self._peers: Dict[str, Any] = {}          # addr -> peer transport
        self._sessions: Dict[str, _Session] = {}
        self._gates: Dict[str, threading.Lock] = {}   # per-object dispense gate
        self._lock = threading.Lock()
        #: replica chains + decision ledger (DESIGN.md §8)
        self.replication = ReplicationManager(self)
        #: ownership leases + epoch fencing + redirect tombstones (§10)
        self.leases = LeaseManager(self)
        #: migration drain-barriers in flight: name -> threading.Event
        #: (set when the migration resolves either way); per-object
        #: access-affinity votes: name -> {node_addr: count}.
        self._migrating: Dict[str, threading.Event] = {}
        self._affinity: Dict[str, Dict[str, int]] = {}
        self._migrate_queue: List[Tuple[str, str]] = []
        self.migrate_auto = False       # affinity-triggered handoff opt-in
        self.n_migrations = 0
        #: §12 commute counters: deltas folded into live state at
        #: terminate, and deltas that arrived as ``commute_delta`` one-ways
        #: (the coordination-avoidance fraction of the write traffic).
        self.n_merged_deltas = 0
        self.n_commute_oneways = 0
        #: observability: one trace track + metric namespace per node,
        #: reading THIS node's clock domain (monotonic vs. sim-virtual).
        #: Created even when tracing is off — a bare Tracer holds no ring
        #: until the first emit, so the disabled cost is one object.
        self.obs_tracer = _txtrace.tracer(f"node:{node_name}", clock=clock)
        self.obs_metrics = _metrics.registry(f"node:{node_name}")
        for shared in self.registry.all_objects().values():
            if shared.node is self.node:
                self._obs_stamp(shared)
        #: write-ahead ledger (§11): None keeps durability entirely off —
        #: the TCP server opts in via --wal-dir, simnet always wires a
        #: seeded VirtualDisk so restart schedules are deterministic.
        self.wal = wal
        self._recovered = wal.recover() if wal is not None else None
        if self._recovered is not None:
            self._apply_wal_recovery(self._recovered)

    def _apply_wal_recovery(self, rec) -> None:
        """Offline half of the §11 restart: rebuild this node's pre-crash
        roles from the replayed ledger. Tombstones and follower replica
        records come back verbatim; the decision ledger is restored so we
        can answer ``txn_status``/``txn_decision`` for transactions we
        coordinated before the crash. Primaries are NOT rebound here —
        whether this node still owns them is decided against the live
        chain in :meth:`rejoin_chains` (epoch monotonicity: a successor's
        higher epoch wins and our fenced state is discarded)."""
        repl = self.replication
        with repl.lock:
            repl.decisions.update(rec.decisions)
        for name, (target, epoch, followers) in rec.tombstones.items():
            self.leases.moved[name] = (target, epoch, list(followers))
        for name, info in rec.objects.items():
            if info["role"] != "follower":
                continue
            r = ReplicaRecord(name, info["primary"], list(info["order"]),
                              info["epoch"], info["payload"],
                              (info["epoch"], info["seq"]))
            r.recovering = True     # not promotable until caught up (§11)
            repl.replicas[name] = r
        # undecided tentatives we buffered as a follower go back into the
        # record so promotion resolves them against the coordinator
        for (txn, name), (epoch, seq, payload, head) in rec.pending.items():
            r = repl.replicas.get(name)
            if r is not None and txn not in repl.decisions:
                r.tentative[txn] = (epoch, seq, payload, head)

    def _obs_stamp(self, shared: SharedObject) -> None:
        """Point the object's version header at this node's obs sinks, so
        versioning's gate-wait/handoff instrumentation lands on the track
        of the node that owns the state."""
        h = shared.header
        h.obs_tracer = self.obs_tracer
        h.obs_metrics = self.obs_metrics
        h.obs_clock = self._clock

    #: transport address peers/followers reach this node at; concrete
    #: transports override (TCP property / simnet attribute).
    address: Optional[str] = None

    def has_binding(self, name: str) -> bool:
        try:
            shared = self.registry.locate(name)
        except KeyError:
            return False
        return shared.node is self.node

    def bind_local(self, name: str, obj: Any) -> None:
        """Bind ``obj`` here under a FRESH version header (promotion path:
        the dead primary's private versions are meaningless on this node —
        in-flight transactions abort and retry against the new header)."""
        try:
            shared = self.registry.bind(name, obj, node=self.node)
        except ValueError:
            return   # already bound here: promotion is idempotent
        self._obs_stamp(shared)
        with self._lock:
            self._gates.setdefault(name, threading.Lock())

    # -- transport hooks -----------------------------------------------------
    @staticmethod
    def _oob(payload: bytes) -> Any:
        """Mark a bulk payload for the transport (overridden per wire)."""
        return payload

    def _gate_acquire(self, gate: threading.Lock, nb: bool = False) -> None:
        """Acquire a version-lock dispensing gate. ``nb`` gives up with
        :class:`_WouldBlock` instead of blocking (reader fast path)."""
        if nb:
            if not gate.acquire(blocking=False):
                raise _WouldBlock
        else:
            gate.acquire()

    def _queue_note(self, conn: Any, note: dict) -> None:
        """Deliver one server push (``task_done`` / ``oneway_err``) on
        ``conn``."""
        raise NotImplementedError

    def _push_target(self, conn: Any, client_id: str) -> Any:
        """The connection a task-completion push for ``client_id`` should
        ride, given the connection the kickoff arrived on (``conn`` may
        belong to a chain-forwarding peer server instead)."""
        return conn

    def reap_stale(self, now: float) -> bool:
        """Expire every session whose client stopped heartbeating before
        ``now - monitor.timeout`` (§3.4) — the one staleness scan shared
        by the TCP real-time reaper thread and the simulation's
        virtual-clock reaper events. Returns True iff sessions remain
        (the caller decides whether to keep polling).

        Lease renewal rides the same cadence: one-way ``lease_renew``
        sends are non-blocking, so the tick is safe in both the TCP
        reaper thread and the simulation's scheduler loop."""
        self.leases.tick(now)
        with self._lock:
            stale = [(uid, s) for uid, s in self._sessions.items()
                     if now - s.last_contact > self.monitor.timeout]
        for uid, session in stale:
            self._expire_session(session)
            with self._lock:
                self._sessions.pop(uid, None)
        with self._lock:
            return bool(self._sessions)

    def _handle_oneway(self, conn: _Conn, op: str, kw: Dict[str, Any]) -> None:
        try:
            self._dispatch(op, kw)
        except BaseException as e:  # noqa: BLE001 - defer to the client
            self._queue_note(conn, {
                "kind": "oneway_err", "op": op, "txn": kw.get("txn"),
                "name": kw.get("name"), "error": encode_error(e)})

    def _push_task_done(self, session: _Session, name: str, conn: _Conn,
                        result: tuple) -> None:
        # The target is the connection the kickoff arrived on: its loss
        # means the whole client process is crash-stop dead (the client
        # fails all local task waits itself), so no fallback is needed.
        error, payload = result
        self._queue_note(conn, {"kind": "task_done", "txn": session.txn_uid,
                                "name": name, "error": error,
                                "buf": payload})

    def _buf_payload(self, acc: _ServerAccess) -> Optional[bytes]:
        """Pickled read-buffer state iff it is small enough to ship (the
        piggyback read protocol); ``None`` keeps reads home-node-only.
        Shares the sticky ``ship_state`` opt-out with the held-state
        piggyback, so a big/unpicklable object pays the wasted
        serialization at most once per access."""
        if not acc.ship_state:
            return None
        with acc.lock:
            buf = acc.buf
        if buf is None:
            return None
        try:
            payload = pickle.dumps(buf.state, protocol=pickle.HIGHEST_PROTOCOL)
        except Exception:  # noqa: BLE001 - unpicklable state stays home
            acc.ship_state = False
            return None
        if len(payload) > PIGGYBACK_MAX:
            acc.ship_state = False
            return None
        return self._oob(payload)   # out-of-band on wire v3; raw in sim

    def _held_payload(self, acc: _ServerAccess) -> Optional[bytes]:
        """Held-state copy for the piggyback live-read protocol: while the
        client holds the access, nobody else can modify the object, so its
        pure reads may run against a shipped copy that every modifying
        reply refreshes. ``None`` (too big / unpicklable) keeps reads
        home-node-only; the decision is sticky per access."""
        if not acc.ship_state:
            return None
        try:
            payload = pickle.dumps(acc.shared.holder.obj,
                                   protocol=pickle.HIGHEST_PROTOCOL)
        except Exception:  # noqa: BLE001
            acc.ship_state = False
            return None
        if len(payload) > PIGGYBACK_MAX:
            acc.ship_state = False
            return None
        return self._oob(payload)   # out-of-band on wire v3; raw in sim

    def _client_vanished(self, client_id: str) -> None:
        """Last mux connection dropped: crash-stop the client's sessions."""
        with self._lock:
            sessions = [s for s in self._sessions.items()
                        if s[1].client_id == client_id]
        for uid, session in sessions:
            self._expire_session(session)
            with self._lock:
                self._sessions.pop(uid, None)

    def _expire_session(self, session: _Session) -> None:
        """Crash-stop one client transaction (paper §3.4).

        Performs the complete self-rollback for every object the session
        dispensed on, directly (not via the object-level monitor — a
        handoff raced successor transactions becoming the holder, dropping
        the rollback and leaving the crashed version unterminated): under
        the version lock, restore the checkpoint if the session modified
        live state and nothing newer restored already (oldest-restore-wins
        on the epoch), bump the instance epoch so observers of the dead
        transaction's state cascade-abort, and skip its private version in
        chain order (:func:`~repro.core.versioning.skip_version`) so
        successors unwedge without ever bypassing a live predecessor — this
        covers held, released-but-unterminated, and never-accessed objects
        alike. Version-lock gates the session still holds are
        force-released.

        ``session.expired`` is set first: the advance below drains waiters,
        including the session's own parked §2.7/§2.8.4 tasks — woken, they
        must no-op rather than apply a dead transaction's buffered writes."""
        session.expired = True
        if _txtrace.enabled:
            self.obs_tracer.instant("expire", txn=session.txn_uid,
                                    detail="§3.4 crash-stop self-rollback",
                                    sev=_txtrace.WARN)
        self._release_gates(session)
        with session.lock:
            accesses = list(session._accesses.items())
        for shared, acc in accesses:
            h = shared.header
            if isinstance(acc, _ServerCommuteAccess):
                # §12: a dead commute member's undelivered deltas are simply
                # discarded — live state was never touched (no restore, no
                # instance bump, nobody cascades). Its private version is
                # the GROUP's shared cg_pv: skipping it would terminate the
                # group under its surviving members, so the member departs
                # instead (the last departure dissolves the group).
                with acc.lock:
                    acc.log.entries.clear()
                    acc.oneway_entries = []
                shared.clear_holder(session)
                acc.commute_depart()
                self.monitor.rollbacks.append(shared.name)
                self.replication.on_abort(session.txn_uid, shared.name)
                continue
            with h.lock:
                # Read access state under the header lock: an lw-apply task
                # holding it is either fully applied (its checkpoint is
                # visible and restored here) or will see `expired` and
                # no-op — never applied-but-unrestored.
                with acc.lock:
                    seen, st, modified = (acc.seen_instance, acc.st,
                                          acc.modified)
                with shared._contact_lock:
                    if shared.holding_txn is session:
                        shared.holding_txn = None
                if (st is not None and modified
                        and h.restore_allowed(seen, acc.pv)):
                    st.restore_into(shared.holder)
                    h.note_restore(acc.pv)
                    h.instance += 1
            skip_version(h, acc.pv)
            self.monitor.rollbacks.append(shared.name)
            # §3.4 expiry IS the abort: discard the dead transaction's
            # tentative replication (followers drop the buffered state).
            self.replication.on_abort(session.txn_uid, shared.name)

    # ------------------------------------------------------------------ #
    # op dispatch                                                         #
    # ------------------------------------------------------------------ #
    def _dispatch(self, op: str, kw: Dict[str, Any]) -> Any:
        handler = getattr(self, f"_op_{op}", None)
        if handler is None:
            raise WireError(f"unknown op {op!r}")
        if _txtrace.enabled:
            # One span per handled op, named after the op itself — so
            # dispense_batch / commit_wave1 / repl_apply / repl_final
            # slices read directly in the Perfetto UI.
            t0 = self.obs_tracer.now()
            v = handler(**kw)
            self.obs_tracer.emit(op, t0, self.obs_tracer.now() - t0,
                                 txn=kw.get("txn") or "", detail="op")
            return v
        return handler(**kw)

    # -- helpers ------------------------------------------------------------
    def _shared(self, name: str) -> SharedObject:
        try:
            return self.registry.locate(name)
        except KeyError:
            # A migrated-away binding: the name is gone from the local
            # registry but the lease layer keeps the epoch-fenced redirect
            # tombstone — raise the redirect (which clients follow) rather
            # than a bare KeyError no transport can act on. Never-bound
            # names still get the KeyError.
            self.leases.check_grant(name)
            rec = self._recovered
            if (rec is not None and name in rec.objects
                    and rec.objects[name].get("role") == "primary"):
                # Restarted primary mid-rejoin (§11): the WAL proves the
                # object was served here, but recovery hasn't written
                # the redirect tombstone (or resurrected the binding)
                # yet — refuse service retryably instead of claiming the
                # name never existed. Restarted followers keep the bare
                # KeyError: they never served it.
                raise RemoteObjectFailure(
                    f"{name!r} is recovering on this node after a "
                    f"restart; retry") from None
            raise

    def _session(self, txn: str) -> _Session:
        with self._lock:
            session = self._sessions.get(txn)
        if session is None:
            # The session was expired (§3.4 crash-stop suspicion) — an
            # "illusorily crashed" client coming back must abort, exactly
            # like a transaction whose observed instance was invalidated.
            raise InstanceInvalidated(
                f"transaction {txn!r} has no live session on this node "
                f"(rolled back by the failure detector)")
        session.last_contact = self._clock()
        return session

    def _acc(self, txn: str, name: str) -> _ServerAccess:
        session = self._session(txn)
        shared = self._shared(name)
        acc = session._accesses.get(shared)
        if acc is None:
            raise InstanceInvalidated(
                f"transaction {txn!r} holds no access on {name!r}")
        return acc

    def _check_valid(self, acc: _ServerAccess) -> None:
        """Per-operation §2.3 validity check, enforced at the home node."""
        if not acc.valid():
            raise InstanceInvalidated(
                f"object {acc.shared.name!r} was invalidated by a cascading "
                f"abort (home-node check)")

    def _peer(self, address: str):
        """Client connection to a peer node server (chain dispensing)."""
        from .client import NodeClient   # lazy: client imports nothing of us
        with self._lock:
            peer = self._peers.get(address)
        if peer is not None and peer.alive:
            return peer
        fresh = NodeClient(address, conns=1)
        with self._lock:
            cur = self._peers.get(address)
            if cur is not None and cur.alive:
                peer = cur
            else:
                self._peers[address] = peer = fresh
        if peer is not fresh:
            fresh.close()
        return peer

    def _release_gates(self, session: _Session) -> None:
        with session.lock:
            gates, session.held_gates = session.held_gates, []
        for g in reversed(gates):
            try:
                g.release()
            except RuntimeError:  # pragma: no cover - already released
                pass

    # -- ownership migration (§10) --------------------------------------------
    def _spawn_bg(self, fn: Callable[[], None], name: str = "bg") -> None:
        """Run a blocking background job (migration drain). The simulation
        overrides this to run the job on a handler actor, on virtual time."""
        threading.Thread(target=fn, name=f"{name}-{self.node_name}",
                         daemon=True).start()

    def _affinity_vote(self, name: str, affinity: str) -> None:
        """Per-object access-affinity tally (§10): every dispense carries
        the client's locality hint; a sustained dominant remote accessor
        triggers a lease handoff to it. Votes are cheap bookkeeping — the
        migration itself is queued and drained off the op path."""
        if not affinity:
            return
        with self._lock:
            tally = self._affinity.setdefault(name, {})
            tally[affinity] = tally.get(affinity, 0) + 1
            if not self.migrate_auto or affinity == self.address:
                return
            votes = tally[affinity]
            rest = max((v for a, v in tally.items() if a != affinity),
                       default=0)
            if votes < MIGRATE_THRESHOLD or votes < 2 * max(rest, 1):
                return
            if (name in self._migrating
                    or any(n == name for n, _t in self._migrate_queue)):
                return
            tally.clear()
            self._migrate_queue.append((name, affinity))

    def _drain_migrations(self) -> None:
        with self._lock:
            pending, self._migrate_queue = self._migrate_queue, []
        for name, target in pending:
            self._spawn_bg(lambda n=name, t=target: self._do_migrate(n, t),
                           name="migrate")

    def _do_migrate(self, name: str, target: str) -> bool:
        """Ownership handoff as a drain-barrier (§10).

        1. Mark the object migrating *under its header lock* — paired with
           the grant check in ``_op_dispense_batch``, so no new version is
           dispensed after the mark.
        2. Drain: wait until every dispensed version terminated
           (``gv == lv == ltv``). After the drain there are no in-flight
           accesses and no undecided tentatives for this object, so the
           applied state is the whole truth — a fresh header at the target
           is exact, like the promotion path.
        3. Ship state + epoch + 1 + the new chain (old primary joins it as
           a follower) via a synchronous ``migrate_in``.
        4. Atomically re-point: unbind here, leave an epoch-fenced redirect
           tombstone; parked dispensers wake, re-check, and raise
           :class:`ObjectMovedError`, which clients follow without
           reconnecting.
        """
        try:
            shared = self._shared(name)
        except KeyError:
            return False
        if shared.node is not self.node or target == self.address:
            return False
        h = shared.header
        ev = threading.Event()
        with h.lock:
            if name in self._migrating:
                return False
            try:
                self.leases.check_grant(name)
            except RemoteObjectFailure:
                return False        # fenced or already moved: nothing to do
            except LeaseRearming:
                return False        # re-ack round in flight: retry later
            self._migrating[name] = ev
        t0 = self.obs_tracer.now() if _txtrace.enabled else 0.0
        ok = False
        try:
            if not wait_quiescent(h, timeout=5 * self.leases.ttl):
                return False        # drain never settled: abort the handoff
            payload = pickle.dumps(shared.holder.obj,
                                   protocol=pickle.HIGHEST_PROTOCOL)
            epoch = self.replication.epochs.get(name, 0) + 1
            chain = [self.address] + [
                f for f in self.replication.followers_of(name)
                if f != target and f != self.address]
            self._peer(target).call("migrate_in", name=name, payload=payload,
                                    epoch=epoch, followers=chain)
            self.replication.drop_primary(name)
            self.registry.unbind(name)
            self.leases.drop_local(name, target, epoch, chain)
            with self._lock:
                self._affinity.pop(name, None)
                self.n_migrations += 1
            ok = True
            return True
        except Exception as e:  # noqa: BLE001 - target died mid-handoff
            log.warning("migration of %r -> %s failed: %r", name, target, e)
            return False
        finally:
            with h.lock:
                self._migrating.pop(name, None)
            ev.set()
            if _txtrace.enabled:
                self.obs_tracer.emit(
                    "migrate", t0, self.obs_tracer.now() - t0,
                    detail=f"{name}->{target}"
                           f"{'' if ok else ' (failed)'}")

    # -- restart + chain rejoin (§11) -----------------------------------------
    def _check_grant_blocking(self, name: str) -> None:
        """``check_grant`` that waits out an idle-lapse re-ack round: a
        :class:`LeaseRearming` parks the handler OUTSIDE the lease/header
        locks on the round's event, then re-checks — the round resolves
        into serving (chain re-acked the epoch), a fence, or a redirect,
        and the re-check surfaces whichever it was."""
        while True:
            try:
                self.leases.check_grant(name)
                return
            except LeaseRearming as e:
                blocking_wait(e.event, self.leases.ttl)

    def _demote_to_follower(self, name: str, successor: str) -> None:
        """A permanently fenced primary demotes itself into the
        successor's chain (§11): drain what's left in flight (every new
        grant already redirects), drop the stale local copy, and rejoin
        as the tail follower so the chain regrows to its bound width.
        Spawned by the lease layer's permanent-fence handler."""
        try:
            shared = self.registry.locate(name)
        except KeyError:
            shared = None
        if shared is not None and shared.node is self.node:
            wait_quiescent(shared.header, timeout=5 * self.leases.ttl)
            self.replication.drop_primary(name)
            try:
                self.registry.unbind(name)
            except KeyError:
                pass
        backoff = max(self.leases.ttl / 2, 4 * self.monitor.poll_interval)
        for _ in range(5):
            if self._rejoin_as_follower(name, successor):
                return
            blocking_wait(threading.Event(), backoff)
        log.warning("deposed primary of %r could not rejoin %s",
                    name, successor)

    def rejoin_chains(self) -> None:
        """Networked half of the §11 restart protocol, run once per boot
        after transports are up. For each object the replayed ledger says
        we participated in:

        1. **Probe** the last known chain members (``chain_probe``).
        2. A live primary at our epoch or higher → we are stale: discard
           fenced local state per epoch monotonicity, rehydrate a redirect
           tombstone at its epoch, and **rejoin** its chain as the tail
           follower via anti-entropy catch-up (``repl_rejoin``).
        3. No primary but a live chain member → drive promotion there
           (first-alive-in-order — the same deterministic failover order
           clients use), then rejoin the winner.
        4. Nobody reachable and the ledger says the object was ours with
           **no followers** → resurrect immediately: nobody else could
           have promoted, so the WAL image is the whole truth. With
           followers we keep probing (they hold the later-epoch evidence)
           and only resurrect as a last resort after the retry window —
           the one residual stale-serve window left open (DESIGN.md §11).
        """
        rec = self._recovered
        if rec is None:
            return
        for name, info in rec.objects.items():
            try:
                self._recover_object(name, info, rec)
            except Exception as e:  # noqa: BLE001 - recovery best-effort
                log.warning("restart recovery of %r failed: %r", name, e)

    def _probe_chain(self, addr: str, name: str) -> Optional[Dict[str, Any]]:
        try:
            p = self._peer(addr).call("chain_probe", name=name,
                                      rpc_timeout=5 * self.leases.ttl)
            return p if isinstance(p, dict) else None
        except Exception:  # noqa: BLE001 - dead peers read as no answer
            return None

    def _recover_object(self, name: str, info: Dict[str, Any],
                        rec: Any, attempts: int = 25) -> None:
        me = self.address
        peers: List[str] = []
        for a in ([info.get("primary")] + list(info.get("order") or ())
                  + list(info.get("followers") or ())):
            if a and a != me and a not in peers:
                peers.append(a)
        backoff = max(self.leases.ttl / 2, 4 * self.monitor.poll_interval)
        for attempt in range(attempts):
            best = None              # (epoch, addr, probe) of live primary
            candidates: List[str] = []   # live followers, failover order
            recovering: List[Dict[str, Any]] = []   # §11 replayed images
            rival = None             # (epoch, addr) of best rival claim
            for addr in peers:
                p = self._probe_chain(addr, name)
                if p is None:
                    continue
                role = p.get("role")
                if role == "moved":
                    t = p.get("target")
                    if t and t != me and t not in peers:
                        peers.append(t)   # chase the redirect next pass
                elif role == "primary":
                    if best is None or p["epoch"] > best[0]:
                        best = (p["epoch"], addr, p)
                elif role == "recovering-primary":
                    # another replayed image also claims the object:
                    # reconcile by (epoch, address) — the greater claim
                    # resurrects, the lesser waits and rejoins it
                    if rival is None or (p["epoch"], addr) > rival:
                        rival = (p["epoch"], addr)
                elif role == "follower" and not p.get("promoted"):
                    if p.get("recovering"):
                        # a replayed, not-yet-caught-up image: refuses
                        # promotion, but its ledger may hold later-epoch
                        # evidence — chase ITS primary too
                        recovering.append(p)
                        pr = p.get("primary")
                        if pr and pr != me and pr not in peers:
                            peers.append(pr)
                    else:
                        candidates.append(addr)
            deferred = (info["role"] == "primary" and rival is not None
                        and rival > (info["epoch"], me))
            if best is not None:
                epoch, addr, p = best
                if info["role"] == "primary":
                    # superseded while down: epoch monotonicity — drop our
                    # fenced image, leave a redirect for stale bindings
                    order = [a for a in p.get("order", ()) if a != addr]
                    self.leases.moved[name] = (addr, epoch, list(order))
                    if self.wal is not None:
                        self.wal.tombstone(name, addr, epoch, list(order))
                if self._rejoin_as_follower(name, addr):
                    return
            elif candidates:
                # headless chain: drive promotion at the first live
                # follower, then rejoin whoever won on the next pass
                try:
                    self._peer(candidates[0]).call(
                        "lease_acquire", names=[name],
                        rpc_timeout=5 * self.leases.ttl)
                except Exception:  # noqa: BLE001 - busy/dead: retry
                    pass
            elif (info["role"] == "primary" and not deferred
                  and recovering and all(
                      p.get("primary") == me
                      and p.get("epoch", 0) <= info["epoch"]
                      for p in recovering)):
                # Every reachable chain member is a recovering follower of
                # OUR epoch (a whole-chain outage, §11): none of them can
                # promote (the recovering guard refuses), so no write has
                # landed since our crash and our own synced ledger — every
                # commit is final'd before the client ack — is the
                # authoritative image. Resurrect; they rejoin us next pass.
                self._resurrect_primary(name, info, rec)
                return
            elif info["role"] == "primary" and not deferred and (
                    not peers or attempt == attempts - 1):
                if peers:
                    log.warning("resurrecting %r with chain %r dark: "
                                "last-resort, state may be stale", name,
                                peers)
                self._resurrect_primary(name, info, rec)
                return
            elif info["role"] == "follower" and attempt == attempts - 1:
                # whole chain dark: keep the replayed replica record —
                # promotion stays client-driven, later restarts rejoin us
                return
            blocking_wait(threading.Event(), backoff)
        log.warning("gave up rejoining chain for %r after %d attempts",
                    name, attempts)

    def _rejoin_as_follower(self, name: str, primary: str) -> bool:
        """Anti-entropy catch-up (§11): ask the live primary to splice us
        back in as the tail follower. The reply is a quiesced snapshot —
        the chain's native replication unit — which replaces whatever
        stale image we replayed (the stale record is popped first so the
        ``repl_init`` staleness guard cannot reject the fresh epoch)."""
        try:
            r = self._peer(primary).call(
                "repl_rejoin", name=name, addr=self.address,
                rpc_timeout=10 * self.leases.ttl)
        except Exception:  # noqa: BLE001 - primary died mid-rejoin: retry
            return False
        if not isinstance(r, dict) or r.get("busy") or "payload" not in r:
            return False
        with self.replication.lock:
            self.replication.replicas.pop(name, None)
        self.replication.repl_init(
            name=name, primary=r["primary"], order=list(r["order"]),
            epoch=r["epoch"], seq=r["seq"], payload=r["payload"])
        return True

    def _resurrect_primary(self, name: str, info: Dict[str, Any],
                           rec: Any) -> None:
        """Rebind a WAL-recovered primary at ``epoch + 1``. Undecided
        tentatives (we crashed between prep and terminate) are resolved
        against their coordinator's decision ledger first. Unlike the
        promotion path (where epoch fencing discards a returning rival's
        contradicting fold), resurrection has no rival chain to defer to
        — so an *unreachable* coordinator here may itself be mid-restart
        holding a durable ``commit``, and dooming on first contact would
        split the decision (§11). Poll through unreachability for the
        full horizon; only a coordinator that stays dark past it (or one
        that is reachable with no record) dooms the tentative to abort."""
        epoch, seq = info["epoch"], info["seq"]
        payload = info["payload"]
        for (txn, n), t in sorted(rec.pending.items()):
            if n != name:
                continue
            head = t[3]
            status = "none"
            if head and head != self.address:
                # a live coordinator still "pending" must eventually abort
                # (its commit wave cannot succeed against our dead
                # sessions) — poll it out briefly, then doom
                for _ in range(10):
                    status = self.replication._query_head(head, txn)
                    if status not in ("pending", "unreachable"):
                        break
                    blocking_wait(threading.Event(), self.leases.ttl / 2)
            d = self.replication.record_decision(
                txn, "commit" if status == "commit" else "abort")
            if d == "commit" and (t[0], t[1]) >= (epoch, seq):
                # fold_payload: a §12 commute delta folds into the
                # recovered snapshot; an exact tentative replaces it.
                epoch, seq = t[0], t[1]
                payload = fold_payload(payload, t[2])
        new_epoch = epoch + 1
        self.bind_local(name, pickle.loads(payload))
        followers = [f for f in info.get("followers", ()) if f != self.address]
        self.replication.adopt(name, followers, new_epoch, payload)
        self.leases.grant_local(name, new_epoch)

    # -- directory ----------------------------------------------------------
    def _op_ping(self) -> Dict[str, Any]:
        return {"node": self.node_name, "time": time.time(),
                "objects": len(self.registry.all_objects())}

    @staticmethod
    def _declared_modes(obj: Any) -> Dict[str, Mode]:
        """All ``@access``-annotated methods of ``obj``'s class — shipped
        with bindings so clients never pay a ``mode_of`` round trip."""
        modes: Dict[str, Mode] = {}
        for n in dir(type(obj)):
            if n.startswith("_"):
                continue
            mode = getattr(getattr(type(obj), n, None), "__access_mode__",
                           None)
            if mode is not None:
                modes[n] = mode
        return modes

    @staticmethod
    def _declared_commutes(obj: Any) -> Dict[str, str]:
        """All ``@access(..., commutes=)`` declarations of ``obj``'s class
        — shipped with bindings like the modes, so commute-aware clients
        build :class:`~repro.net.remote.RemoteCommuteAccess` records
        without a round trip. Empty for undeclared classes (the common
        case), keeping the wire byte-identical to the pre-§12 protocol."""
        return commute_classes(obj)

    def _op_list_bindings(self) -> Dict[str, Any]:
        objs = self.registry.all_objects()
        followers = {name: fl for name in objs
                     if (fl := self.replication.followers_of(name))}
        commutes = {name: cm for name, shared in objs.items()
                    if (cm := self._declared_commutes(shared.holder.obj))}
        out = {"node": self.node_name,
               "bindings": {name: self._declared_modes(shared.holder.obj)
                            for name, shared in sorted(objs.items())},
               "followers": followers}
        if commutes:
            out["commutes"] = commutes
        return out

    def _op_bind(self, name: str, obj: Any,
                 followers: List[str] = ()) -> Dict[str, Any]:
        self._obs_stamp(self.registry.bind(name, obj, node=self.node))
        with self._lock:
            self._gates[name] = threading.Lock()
        # unconditional: follower-less binds still hit the WAL (when one
        # is configured) so the object is resurrectable after a crash
        self.replication.set_followers(name, list(followers), obj)
        # Ownership starts as a lease (§10): granted at the binding epoch,
        # renewed over the chain. Follower-less binds self-renew trivially.
        self.leases.grant_local(name, self.replication.epochs.get(name, 0))
        return {"modes": self._declared_modes(obj),
                "commutes": self._declared_commutes(obj)}

    def _op_commute_classes(self, name: str) -> Dict[str, str]:
        return self._declared_commutes(self._shared(name).holder.obj)

    def _op_mode_of(self, name: str, method: str) -> Mode:
        return method_mode(self._shared(name).holder.obj, method)

    def _op_raw_call(self, name: str, method: str, args: tuple,
                     kwargs: dict) -> Any:
        """Non-transactional direct invocation (Registry-level access)."""
        self._check_grant_blocking(name)
        return self._shared(name).raw_call(method, args, kwargs)

    # -- header surface (RemoteHeader duck type) -----------------------------
    def _op_header_state(self, name: str) -> Dict[str, int]:
        h = self._shared(name).header
        with h.lock:
            return {"gv": h.gv, "lv": h.lv, "ltv": h.ltv,
                    "instance": h.instance}

    def _op_header_wait(self, name: str, kind: str, pv: int,
                        timeout: Optional[float]) -> bool:
        h = self._shared(name).header
        if kind == "termination":
            return h.wait_termination(pv, timeout=timeout)
        return h.wait_access(pv, timeout=timeout)

    def _op_header_release(self, name: str, pv: int) -> None:
        self._shared(name).header.release_to(pv)

    def _op_header_terminate(self, name: str, pv: int) -> None:
        self._shared(name).header.terminate_to(pv)

    # -- start: batched version dispensing (§2.10.2) -------------------------
    def _op_dispense_batch(self, txn: str, client_id: str, names: List[str],
                           ro_names: List[str] = (), kind: str = "access",
                           chain: List[dict] = (), affinity: str = "",
                           commute: Optional[Dict[str, str]] = None,
                           _conn: Optional[_Conn] = None,
                           _nb: bool = False) -> Dict[str, Any]:
        """Lock-and-dispense for this node's batch; then *forward the
        chain*: the remaining per-node batches, in global 2PL order, go
        server-to-server (this node calls the next) while this node's
        gates stay held — a multi-node start costs the end client one
        round trip, and every gate-hold window spans a server hop instead
        of a client bounce. The aggregated reply carries all nodes' pvs.

        The §2.7 read-only buffering kickoffs for ``ro_names`` ride along:
        tasks whose gate is already open complete during this RPC and
        their results (buffer state included, when small) ride back on the
        reply — the uncontended §2.7 hot path costs *zero* messages beyond
        the dispense itself."""
        with self._lock:
            session = self._sessions.get(txn)
            if session is None:
                session = self._sessions[txn] = _Session(
                    txn, client_id, now=self._clock())
        objs = [(self._shared(n), n) for n in names]
        objs.sort(key=lambda sn: sn[0].header.uid)   # node-local global order
        pvs: Dict[str, int] = {}
        made: Dict[str, _ServerAccess] = {}
        acquired: List[threading.Lock] = []
        try:
            for shared, name in objs:
                with self._lock:
                    gate = self._gates.setdefault(name, threading.Lock())
                # Reader fast path (``_nb``): give up (and redo on the
                # pool) rather than block the connection on a held gate.
                # How a *blocking* acquire blocks is the transport's
                # business (virtual-time backoff under simnet).
                self._gate_acquire(gate, nb=_nb)
                acquired.append(gate)
            for shared, name in objs:
                # Lease fence + drain-barrier (§10): no version is ever
                # granted by a fenced primary or while a migration is
                # draining the header. Both checks sit under the header
                # lock, paired with `_do_migrate` which marks the object
                # under the same lock — so a grant and a drain snapshot
                # can never interleave.
                cls = commute.get(name) if commute else None
                joined = False
                while True:
                    rearm = None
                    with shared.header.lock:
                        ev = self._migrating.get(name)
                        if ev is None:
                            try:
                                self.leases.check_grant(name)
                                # §12: a commute-declared access tries the
                                # group first; 0 (other class / snapped /
                                # chain not quiescent) falls back to exact
                                # dispensing — invisible to the client.
                                joined = bool(
                                    cls is not None
                                    and (pv := shared.header.commute_join(
                                        cls)))
                                if not joined:
                                    pv = shared.header.dispense()
                                break
                            except LeaseRearming as e:
                                # idle-lapse re-ack round (§10): park
                                # OUTSIDE the header lock until the chain
                                # re-acks (or fences) the epoch, then redo
                                rearm = e.event
                    blocking_wait(rearm if rearm is not None else ev,
                                  self.leases.ttl if rearm is not None
                                  else None)
                self._affinity_vote(name, affinity)
                acc = (_ServerCommuteAccess(self, session, shared, pv, cls)
                       if joined else
                       _ServerAccess(self, session, shared, pv))
                with session.lock:   # heartbeats iterate _accesses live
                    session._accesses[shared] = acc
                made[name] = acc
                pvs[name] = pv
        except BaseException:
            for g in reversed(acquired):
                g.release()
            raise
        with session.lock:
            session.held_gates.extend(acquired)
        # §3.4 re-check: the client may have crashed (and its session been
        # expired and dropped) while this handler was parked on the gates
        # — the expiry saw no accesses and no held gates, so whatever we
        # just dispensed would live in a *ghost* session no reaper ever
        # visits, wedging every successor on the version chain forever
        # (found by the simnet seed sweep). Converge it ourselves: skip
        # the dispensed versions in chain order and free the gates — both
        # idempotent against a racing expiry that did see partial state.
        if session.expired:
            self._release_gates(session)
            for name, pv in pvs.items():
                acc = made[name]
                if isinstance(acc, _ServerCommuteAccess):
                    # the group's shared version must not be skipped under
                    # its surviving members — depart instead (idempotent
                    # against a racing expiry that saw the access)
                    acc.commute_depart()
                else:
                    skip_version(self._shared(name).header, pv)
            raise InstanceInvalidated(
                f"transaction {txn!r} crash-stopped during dispense "
                f"(§3.4); dispensed versions skipped")
        # Completion-note target: the connection the request came in on if
        # it belongs to the end client, else (chain-forwarded: the request
        # came from a peer server) a connection the end client keeps to
        # this node. A miss is safe — joins fall back to task_join.
        push_to = self._push_target(_conn, client_id)
        ro: Dict[str, Optional[dict]] = {}
        for name in ro_names:
            acc = self._acc(txn, name)
            acc.inline_tasks = True   # open gate ⇒ complete within this RPC
            acc.spawn_ro_buffer(kind)
            acc.inline_tasks = False
            session.tasks[name] = acc.release_task
            # Delivery handshake (see _ServerAccess._submit_task): if the
            # task already completed, carry its result on this reply;
            # otherwise arm the push and the completion will send a note.
            with acc.lock:
                if acc.task_result is not None and not acc.push_done:
                    acc.push_done = True
                    ro[name] = {"error": acc.task_result[0],
                                "buf": acc.task_result[1]}
                else:
                    acc.push_conn = push_to
                    ro[name] = None
        if chain:
            head, rest = chain[0], list(chain[1:])
            fwd: Dict[str, Any] = {}
            if head.get("commute"):
                fwd["commute"] = head["commute"]
            sub = self._peer(head["address"]).call(
                "dispense_batch", txn=txn, client_id=client_id,
                names=head["names"], ro_names=head["ro_names"], kind=kind,
                chain=rest, **fwd)
            pvs.update(sub["pvs"])
            ro.update(sub["ro"])
        return {"pvs": pvs, "ro": ro}

    def _op_release_version_locks(self, txn: str) -> None:
        self._release_gates(self._session(txn))

    # -- §2.7 / §2.8.4: asynchronous home-node tasks -------------------------
    def _op_ro_buffer(self, txn: str, name: str, kind: str,
                      _conn: Any = None) -> None:
        session = self._session(txn)
        acc = self._acc(txn, name)
        acc.push_conn = _conn
        acc.inline_tasks = self.INLINE_KICKOFF_TASKS
        try:
            acc.spawn_ro_buffer(kind)
        finally:
            acc.inline_tasks = False
        session.tasks[name] = acc.release_task

    def _op_lw_apply(self, txn: str, name: str, kind: str,
                     entries: List[tuple],
                     _conn: Any = None) -> None:
        session = self._session(txn)
        acc = self._acc(txn, name)
        acc.push_conn = _conn
        acc.log.entries = list(entries)
        acc.inline_tasks = self.INLINE_KICKOFF_TASKS
        try:
            acc.spawn_lastwrite_apply(kind)
        finally:
            acc.inline_tasks = False
        session.tasks[name] = acc.release_task

    def _op_task_join(self, txn: str, name: str) -> Dict[str, Any]:
        session = self._session(txn)
        task = session.tasks.get(name)
        if task is None:
            raise InstanceInvalidated(
                f"transaction {txn!r} has no pending task on {name!r}")
        task.join()   # re-raises transactional task errors to the client
        return {"buf": self._buf_payload(self._acc(txn, name))}

    # -- synchronous session state operations --------------------------------
    def _op_open_access(self, txn: str, name: str, kind: str,
                        timeout: Optional[float]) -> Dict[str, Any]:
        acc = self._acc(txn, name)
        blocked = acc.open_access(kind, timeout)
        return {"blocked": blocked, "instance": acc.seen_instance}

    def _op_open_call(self, txn: str, name: str, kind: str,
                      timeout: Optional[float], entries: List[tuple],
                      method: str, args: tuple, kwargs: dict,
                      modifies: bool, want_state: bool = True,
                      tail: List[tuple] = ()) -> Dict[str, Any]:
        """§2.8.2-3 first direct access, fused into one RPC: gate wait +
        checkpoint + buffered-write apply + the method call itself — plus
        ``tail``, the rest of a fusable operation run ``[(method, args,
        kwargs, modifies), ...]`` executed FIFO right behind it (operation
        fusion: the whole read-modify-write hop of a bank-transfer chain
        is one round trip). A mid-tail failure reports ``(error_index,
        error)`` with the prefix applied, like ``txn_call_batch``.
        ``want_state`` (the client still has pure reads ahead) requests a
        held-state copy on the reply."""
        acc = self._acc(txn, name)
        blocked = acc.open_access(kind, timeout)
        if entries:
            acc.log.entries = list(entries)
            acc.apply_log()
        self._check_valid(acc)
        values: List[Any] = [acc.raw_call(method, args, kwargs,
                                          modifies=modifies)]
        error = error_index = None
        for i, (m, a, k, mod) in enumerate(tail):
            try:
                self._check_valid(acc)
                values.append(acc.raw_call(m, a, k, modifies=mod))
            except BaseException as e:  # noqa: BLE001 - serialize to peer
                error, error_index = encode_error(e), i + 1
                break
        acc.note_contact()
        return {"blocked": blocked, "instance": acc.seen_instance,
                "value": values[0], "values": values,
                "error_index": error_index, "error": error,
                "state": (self._held_payload(acc)
                          if want_state and error is None else None)}

    def _op_txn_call(self, txn: str, name: str, method: str, args: tuple,
                     kwargs: dict, modifies: bool,
                     want_state: bool = True) -> Any:
        acc = self._acc(txn, name)
        self._check_valid(acc)
        v = acc.raw_call(method, args, kwargs, modifies=modifies)
        acc.note_contact()
        if modifies:
            # Refresh the client's held-state copy (piggyback live reads):
            # the state can only change through this transaction's own
            # modifying calls, each of which renews the copy. Skipped when
            # the client has no pure reads left to serve from it.
            return {"value": v,
                    "state": self._held_payload(acc) if want_state else None}
        return v

    def _op_txn_call_batch(self, txn: str, name: str, calls: List[tuple],
                           want_state: bool = True,
                           raise_errors: bool = False) -> Dict[str, Any]:
        """Operation fusion (§2.8): a run of consecutive operations against
        one *held* object, executed FIFO-atomically in a single RPC.
        ``calls`` is ``[(method, args, kwargs, modifies), ...]``. Atomicity
        is by exclusion — the transaction holds the access, so nothing
        interleaves — and errors carry an **index**: on a failure at call
        ``i`` the prefix ``[0, i)`` is applied, the suffix is not executed,
        and the reply reports ``(error_index, error)`` so the client can
        restore exact sequential semantics (counters for the prefix, the
        original exception for call ``i``).

        Also accepted as a **one-way** (an all-write batch past the
        transaction's last read needs no values): ``raise_errors`` makes a
        mid-batch failure raise after the prefix applied, so the one-way
        machinery defers it as an ``oneway_err`` note to the next sync
        point instead of it vanishing with the discarded reply."""
        acc = self._acc(txn, name)
        values: List[Any] = []
        error = error_index = None
        modified = False
        for i, (method, args, kwargs, modifies) in enumerate(calls):
            try:
                self._check_valid(acc)
                values.append(acc.raw_call(method, args, kwargs,
                                           modifies=modifies))
                modified = modified or modifies
            except BaseException as e:  # noqa: BLE001 - serialize to peer
                if raise_errors:
                    raise
                error, error_index = encode_error(e), i
                break
        acc.note_contact()
        state = (self._held_payload(acc)
                 if modified and want_state and error is None else None)
        return {"values": values, "error_index": error_index,
                "error": error, "state": state}

    def _op_buf_call(self, txn: str, name: str, method: str, args: tuple,
                     kwargs: dict, want_buf: bool = False) -> Any:
        """Buffered read. ``want_buf`` additionally returns the buffer's
        pickled state when small (piggyback read protocol) so the client's
        subsequent reads of this buffer are local."""
        acc = self._acc(txn, name)
        self._check_valid(acc)
        with acc.lock:
            buf = acc.buf
        if buf is None:
            raise RuntimeError(f"no read buffer for {name!r} in {txn!r}")
        v = buf.call(method, args, kwargs)
        if want_buf:
            return {"value": v, "buf": self._buf_payload(acc)}
        return v

    def _op_apply_log(self, txn: str, name: str,
                      entries: List[tuple]) -> None:
        acc = self._acc(txn, name)
        self._check_valid(acc)
        acc.log.entries = list(entries)
        acc.apply_log()

    def _op_buffer_snapshot(self, txn: str, name: str) -> Optional[bytes]:
        acc = self._acc(txn, name)
        acc.snapshot_buf()
        return self._buf_payload(acc)

    def _op_snap_release(self, txn: str, name: str) -> None:
        """§2.8.3-4 release point as a one-way: snapshot for trailing
        reads, then release. The buffer stays home; the client's first
        trailing read fetches it via ``buf_call(want_buf=True)``."""
        acc = self._acc(txn, name)
        acc.snapshot_buf()
        acc.release()

    def _op_ensure_checkpoint(self, txn: str, name: str) -> int:
        acc = self._acc(txn, name)
        with acc.lock:
            if acc.seen_instance is not None:
                return acc.seen_instance
        acc.ensure_checkpoint()
        return acc.seen_instance

    def _op_release(self, txn: str, name: str) -> None:
        self._acc(txn, name).release()

    def _op_wait_termination(self, txn: str, name: str,
                             timeout: Optional[float]) -> bool:
        return self._acc(txn, name).wait_termination(timeout)

    def _op_wait_termination_batch(self, txn: str, names: List[str],
                                   timeout: Optional[float],
                                   best_effort: bool = False) -> int:
        """Commit step 2 for this node's batch: one RPC, one server thread
        parked on the slowest commit condition. Returns how many of the
        waits actually blocked (the client's ``waits`` statistic). The
        batch semantics (best-effort continuation) are the base class's —
        session accesses ARE ObjectAccess records."""
        accs = [self._acc(txn, n) for n in names]
        if not accs:
            return 0
        return accs[0].wait_termination_batch_async(
            accs, timeout, best_effort=best_effort).result()

    def _op_validate(self, txn: str, names: List[str]) -> List[str]:
        """Commit step 4, batched per node: names whose instance moved."""
        return [name for name in names if not self._acc(txn, name).valid()]

    def _lazy_commute_acc(self, txn: str, client_id: Optional[str],
                          name: str, cls: str) -> _ServerAccess:
        """Get-or-create the access record for a late commute join (§12).

        A commute-only single-domain transaction skips the dispense RPC
        entirely (coordination avoidance): its session and access are
        created lazily at the first ``commute_delta`` one-way or at
        ``commit_solo``. Joining the group needs no 2PL window — one
        object, one domain — so the dispense gate is taken only for the
        join itself (serializing with migration drains) and released
        immediately. When the group cannot be joined the access falls back
        to exact dispensing: a late start on a single node, gated like any
        newcomer behind the chain it joined late."""
        with self._lock:
            session = self._sessions.get(txn)
            if session is None:
                session = self._sessions[txn] = _Session(
                    txn, client_id or txn, now=self._clock())
        session.last_contact = self._clock()
        shared = self._shared(name)
        with session.lock:
            acc = session._accesses.get(shared)
        if acc is not None:
            return acc
        with self._lock:
            gate = self._gates.setdefault(name, threading.Lock())
        self._gate_acquire(gate)
        try:
            while True:
                rearm = None
                with shared.header.lock:
                    ev = self._migrating.get(name)
                    if ev is None:
                        try:
                            self.leases.check_grant(name)
                            joined = bool(
                                pv := shared.header.commute_join(cls))
                            if not joined:
                                pv = shared.header.dispense()
                            break
                        except LeaseRearming as e:
                            rearm = e.event
                blocking_wait(rearm if rearm is not None else ev,
                              self.leases.ttl if rearm is not None else None)
        finally:
            gate.release()
        acc = (_ServerCommuteAccess(self, session, shared, pv, cls)
               if joined else
               _ServerAccess(self, session, shared, pv))
        with session.lock:
            session._accesses[shared] = acc
        # §3.4 re-check, mirroring dispense_batch: a session expired while
        # we were parked above must not leave a ghost version behind.
        if session.expired:
            if isinstance(acc, _ServerCommuteAccess):
                acc.commute_depart()
            else:
                skip_version(shared.header, pv)
            raise InstanceInvalidated(
                f"transaction {txn!r} crash-stopped during its late "
                f"commute join on {name!r} (§3.4)")
        return acc

    def _op_commute_delta(self, txn: str, client_id: str, name: str,
                          cls: str, entries: List[tuple]) -> None:
        """One flushed batch of commuting deltas, shipped as a one-way
        ahead of commit (§12). Buffered on the access — NEVER applied here:
        the fold waits for the commit decision (terminate). Arrives on the
        client's FIFO connection, so buffer order == issue order, and the
        commit RPC that follows it can never overtake."""
        acc = self._lazy_commute_acc(txn, client_id, name, cls)
        with acc.lock:
            acc.oneway_entries.extend(entries)
        self.n_commute_oneways += len(entries)

    def _op_commit_wave1(self, txn: str, items: List[tuple],
                         timeout: Optional[float],
                         origin: Optional[str] = None) -> Dict[str, Any]:
        """Commit steps 2-4 for this node's whole batch in one RPC: wait
        the commit condition per object, checkpoint/apply/release per
        object, then validate the batch. ``items`` is ``[(name, log
        entries), ...]``. Termination (step 5) is deliberately NOT here —
        it must wait for every node's validation verdict. ``origin`` names
        the chained commit's coordinator (None outside a chain): tentative
        replication ships it so a promoting follower knows whom to ask."""
        # Lease fence (§10): a primary that lost its lease must not apply
        # commits — the promoted follower's epoch owns the object now. The
        # abort/rollback paths deliberately stay fence-free (converging
        # versions must always work, or survivors wedge).
        for name, _entries in items:
            self._check_grant_blocking(name)
        blocked = 0
        for name, _entries in items:
            if self._acc(txn, name).wait_termination(timeout):
                blocked += 1
        for name, entries in items:
            acc = self._acc(txn, name)
            acc.absorb_entries(entries)
            acc.repl_origin = origin
            acc.commit_prep()
        bad = [name for name, _e in items
               if not self._acc(txn, name).valid()]
        return {"blocked": blocked, "bad": bad}

    def _op_commit_solo(self, txn: str, items: List[tuple],
                        timeout: Optional[float],
                        client_id: Optional[str] = None,
                        commute: Optional[Dict[str, str]] = None,
                        commute_counts: Optional[Dict[str, int]] = None
                        ) -> Dict[str, Any]:
        """Steps 2-5 of a single-domain commit in one RPC: this node holds
        the whole access set, so its validation verdict alone decides
        termination, and the session ends with it.

        ``commute`` maps commute-declared access names to their method
        class (§12): a deferred-start transaction (commute-only, single
        domain) never dispensed, so its accesses are created here — the
        late group join IS its start. Names already dispensed come back
        from :meth:`_lazy_commute_acc` unchanged."""
        if commute:
            for name, cls in commute.items():
                self._lazy_commute_acc(txn, client_id, name, cls)
        if commute_counts:
            # Torn-delta fence: every delta the client recorded must be
            # here (one-way flushes + commit-riding remainder) or the fold
            # would commit a partial effect set — possible only when an
            # illusory-crash expiry discarded the flushed prefix before
            # this commit lazily re-created the session. Abort instead.
            by_name = dict(items)
            for name, total in commute_counts.items():
                acc = self._acc(txn, name)
                with acc.lock:
                    got = (len(acc.oneway_entries)
                           + len(by_name.get(name) or ()))
                if got != total:
                    raise InstanceInvalidated(
                        f"commute delta set on {name!r} is torn "
                        f"({got}/{total} deltas reached the home node); "
                        f"transaction {txn!r} must abort")
        res = self._op_commit_wave1(txn, items, timeout)
        if not res["bad"]:
            self._op_finish_batch(txn, [n for n, _e in items], end=True)
        return res

    # -- chained commit decision (DESIGN.md §8) ------------------------------
    def _op_commit_wave(self, txn: str, items: List[tuple],
                        timeout: Optional[float] = None,
                        chain: List[dict] = (),
                        origin: Optional[str] = None) -> Dict[str, Any]:
        """One hop of the chained commit wave: steps 2-4 for this node,
        then forward the remaining per-node batches server-to-server. A
        bad verdict short-circuits (no decision can follow, so running the
        remaining waves buys nothing — the client's abort path converges
        every node either way). A dead downstream node raises back along
        the chain to the coordinator, which surfaces it to the client."""
        res = self._op_commit_wave1(txn, items, timeout, origin=origin)
        blocked, bad = res["blocked"], list(res["bad"])
        if not bad and chain:
            nxt, rest = chain[0], list(chain[1:])
            sub = self._peer(nxt["address"]).call(
                "commit_wave", txn=txn, items=nxt["items"], timeout=timeout,
                chain=rest, origin=origin)
            blocked += sub["blocked"]
            bad.extend(sub["bad"])
        return {"blocked": blocked, "bad": bad}

    def _op_commit_chain(self, txn: str, items: List[tuple],
                         timeout: Optional[float] = None,
                         chain: List[dict] = ()) -> Dict[str, Any]:
        """The coordinator end of the chained multi-domain commit: ONE
        client RPC covers steps 2-5 for *every* remote domain.

        This node (first in global domain order) runs its own wave, chains
        the remaining waves server-to-server, and — iff every domain
        validated — makes the commit decision *here*, not at the client:
        record it, replicate it to this node's own followers (with the
        remaining decision chain, so the decision survives this node), then
        terminate locally and drive the decision chain. The client merely
        learns the outcome; its crash after send can no longer leave a
        partially terminated commit (the §3.4 step-5 window, now CLOSED).
        """
        res = self._op_commit_wave(txn, items, timeout=timeout, chain=chain,
                                   origin=self.address)
        if res["bad"]:
            return {"blocked": res["blocked"], "bad": res["bad"],
                    "decided": False}
        decision_chain = [{"address": e["address"],
                           "names": [n for n, _e in e["items"]],
                           "followers": e.get("followers") or {}}
                          for e in chain]
        self.replication.record_decision(txn, "commit", decision_chain)
        self.replication.broadcast_decision(txn, decision_chain)
        try:
            self._op_finish_batch(txn, [n for n, _e in items],
                                  best_effort=True, end=True)
        except TransactionError as e:
            # A §3.4 expiry raced the decision (detector timeout ≪ commit
            # latency — misconfiguration): epochs keep state consistent,
            # the commit still drives to completion everywhere else.
            log.warning("coordinator-local finish failed for %r: %r", txn, e)
        self._drive_decision(txn, decision_chain)
        self.replication.mark_ended(txn)   # ledger GC: retirable once acked
        return {"blocked": res["blocked"], "bad": [], "decided": True}

    def _op_commit_decide(self, txn: str, names: List[str],
                          followers: Optional[Dict[str, List[str]]] = None,
                          chain: List[dict] = ()) -> Dict[str, Any]:
        """One hop of the chained commit *decision* (step 5): record the
        decision (idempotent, first-writer-wins), finish the local batch if
        this node holds the session (primary path) — a follower that was
        promoted mid-commit instead applies its buffered tentatives via the
        decision ledger — and forward one hop. An unreachable downstream
        node is reported back to the driver as ``failed_chain`` for
        redirection to that node's followers."""
        self.replication.record_decision(txn, "commit")
        with self._lock:
            has_session = txn in self._sessions
        # A redirect can land here with a *dead* node's names while this
        # node holds a live session for the same txn (it was a participant
        # domain too): finish only names actually bound here, and keep the
        # session open unless this hop covers its own full batch — the
        # node's own decide hop is still in flight.
        local = [n for n in names if self.has_binding(n)]
        if has_session and local:
            try:
                self._op_finish_batch(txn, local, best_effort=True,
                                      end=len(local) == len(names))
            except TransactionError as e:
                log.warning("decision finish failed for %r on %s: %r",
                            txn, self.node_name, e)
        if not chain:
            return {}
        nxt, rest = chain[0], list(chain[1:])
        try:
            sub = self._peer(nxt["address"]).call(
                "commit_decide", txn=txn, names=nxt["names"],
                followers=nxt.get("followers"), chain=rest) or {}
        except Exception:  # noqa: BLE001 - downstream node died mid-chain
            return {"failed_chain": [dict(e) for e in chain]}
        if sub.get("failed_chain"):
            return {"failed_chain": sub["failed_chain"]}
        return {}

    def _drive_decision(self, txn: str, chain: List[dict]) -> None:
        """Drive the commit decision down the chain, redirecting around
        dead nodes: when a hop fails, the failed entry's names get the
        decision delivered directly to their replica followers (idempotent
        — the ledger is first-writer-wins) and the drive continues with the
        rest of the chain. Best-effort by design: every alive node with a
        stake in ``txn`` ends up with the decision; names whose primary
        died with no replica configured die with it (documented residual).
        """
        chain = [dict(e) for e in chain]
        for _ in range(len(chain) + 4):
            if not chain:
                return
            nxt, rest = chain[0], chain[1:]
            try:
                sub = self._peer(nxt["address"]).call(
                    "commit_decide", txn=txn, names=nxt["names"],
                    followers=nxt.get("followers"), chain=rest) or {}
                failed = sub.get("failed_chain")
            except Exception:  # noqa: BLE001 - first hop died
                failed = chain
            if not failed:
                return
            entry, chain = dict(failed[0]), [dict(e) for e in failed[1:]]
            self._redirect_decision(txn, entry)
        log.warning("decision drive for %r did not converge", txn)

    def _redirect_decision(self, txn: str, entry: Dict[str, Any]) -> None:
        """Deliver the commit decision for a dead node's names to their
        replica followers, first-alive-in-order (the same order every
        client's failover uses, so primaries converge deterministically)."""
        followers = entry.get("followers") or {}
        for name in entry["names"]:
            fl = list(followers.get(name) or ())
            for addr in fl:
                try:
                    self._peer(addr).call(
                        "commit_decide", txn=txn, names=[name],
                        followers={name: fl}, chain=[])
                    break
                except Exception:  # noqa: BLE001 - try the next follower
                    continue
            else:
                log.warning("commit decision for %r undeliverable for %r "
                            "(primary dead, no live replica)", txn, name)

    def _op_rollback(self, txn: str, name: str) -> None:
        acc = self._acc(txn, name)
        acc.mark_aborted()     # a stale commit wave must not apply after us
        acc.rollback()
        self.replication.on_abort(txn, name)

    def _op_rollback_batch(self, txn: str, names: List[str]) -> None:
        for name in names:
            acc = self._acc(txn, name)
            acc.mark_aborted()
            acc.rollback()
            self.replication.on_abort(txn, name)

    def _op_terminate(self, txn: str, name: str) -> None:
        acc = self._acc(txn, name)
        acc.terminate()
        with acc.lock:
            acc.released = True
        self.replication.on_terminate(txn, name)

    def _op_finish_batch(self, txn: str, names: List[str],
                         best_effort: bool = False,
                         end: bool = False) -> None:
        """Commit step 5 / abort step 4 for this node's batch: release and
        terminate every named access. ``end`` additionally drops the
        session (folds the trailing ``end_txn`` message into this RPC).
        ``best_effort`` keeps finishing past a dead access but still
        reports the first failure afterwards — on the one-way commit path
        that becomes an ``oneway_err`` note, so a terminate racing a §3.4
        expiry is at least visible at the client."""
        first_error: Optional[BaseException] = None
        for name in names:
            try:
                acc = self._acc(txn, name)
                acc.release()
                acc.terminate()
                with acc.lock:
                    acc.released = True
                self.replication.on_terminate(txn, name)
            except TransactionError as e:
                if not best_effort:
                    raise
                if first_error is None:
                    first_error = e
        if end:
            self._op_end_txn(txn)
        if first_error is not None:
            raise first_error

    # -- liveness ------------------------------------------------------------
    def _op_touch(self, txn: str, name: str) -> None:
        session = self._session(txn)
        self._shared(name).touch(session)

    def _op_clear_holder(self, txn: str, name: str) -> None:
        session = self._session(txn)
        self._shared(name).clear_holder(session)

    def _op_heartbeat(self, client_id: str, txns: List[str]) -> None:
        now = self._clock()
        for uid in txns:
            with self._lock:
                session = self._sessions.get(uid)
            if session is None:
                continue
            session.last_contact = now
            with session.lock:
                accesses = list(session._accesses.items())
            for shared, acc in accesses:
                # Refresh the failure detector for every object this live
                # session still nominally holds — including released-but-
                # unterminated ones (their last_contact would otherwise
                # freeze while the client blocks in commit, and the object
                # monitor would spuriously roll a *live* client back).
                with shared._contact_lock:
                    if shared.holding_txn is session:
                        shared.last_contact = now

    def _op_end_txn(self, txn: str) -> None:
        with self._lock:
            session = self._sessions.pop(txn, None)
        if session is None:
            return
        with session.lock:
            unterminated = any(not acc.terminated
                               for acc in session._accesses.values())
        if unterminated:
            # Ending a session that still owns live versions (e.g. the
            # client closed out after a partially-failed chained start it
            # never learned the versions of): run the §3.4 self-rollback
            # so the dispensed versions are skipped, not leaked — a leaked
            # version wedges every successor forever.
            self._expire_session(session)
        else:
            # A dispense handler for this very transaction may still be
            # parked on a gate (chained start whose head node died before
            # this close-out arrived): flag the popped session so the
            # handler's post-gate re-check skips whatever it dispenses
            # into it — otherwise those gates and versions leak in a
            # ghost session no reaper ever visits.
            session.expired = True
            self._release_gates(session)
        # Quiet point: queued affinity-triggered handoffs start now, off
        # the op path (the drain would stall this reply otherwise).
        self._drain_migrations()

    def _op_abandon(self, txn: str) -> None:
        """Failed-start cleanup: expire the session now (chain-order skip
        of its dispensed versions; nothing was accessed, so no restores)."""
        with self._lock:
            session = self._sessions.pop(txn, None)
        if session is not None:
            self._expire_session(session)

    # -- replica chains + failover (DESIGN.md §8) ----------------------------
    def _op_repl_init(self, **kw: Any) -> None:
        self.replication.repl_init(**kw)

    def _op_repl_apply(self, **kw: Any) -> None:
        self.replication.repl_apply(**kw)

    def _op_repl_final(self, **kw: Any) -> None:
        self.replication.repl_final(**kw)

    def _op_repl_drop(self, **kw: Any) -> None:
        self.replication.repl_drop(**kw)

    def _op_repl_decision(self, **kw: Any) -> None:
        self.replication.repl_decision(**kw)

    def _op_promote(self, names: List[str]) -> Dict[str, List[str]]:
        """Caller-driven failover: try to become primary for ``names``
        (idempotent). See :meth:`ReplicationManager.promote`."""
        return self.replication.promote(list(names))

    # -- restart protocol (§11) ----------------------------------------------
    def _op_chain_probe(self, name: str) -> Dict[str, Any]:
        """A restarting node asks: what is ``name`` to you, right now?
        Pure read — primaries report their chain, followers their record,
        tombstones their redirect. The prober folds the answers into the
        §11 recovery decision (rejoin / drive promotion / resurrect)."""
        # tombstone first: a deposed primary may briefly keep its stale
        # binding while the demotion drain runs — it is NOT the primary
        m = self.leases.moved.get(name)
        if m is not None:
            return {"role": "moved", "target": m[0], "epoch": m[1]}
        if self.has_binding(name):
            return {"role": "primary",
                    "epoch": self.replication.epochs.get(name, 0),
                    "order": self.replication.followers_of(name)}
        rec = self.replication.replicas.get(name)
        if rec is not None:
            return {"role": "follower", "epoch": rec.applied[0],
                    "primary": rec.primary, "order": list(rec.order),
                    "promoted": rec.promoted,
                    "recovering": rec.recovering}
        w = self._recovered
        if w is not None and name in w.objects \
                and w.objects[name].get("role") == "primary":
            # Restarted, not yet rebound, but the ledger says the object
            # was served HERE: answer with the claim + epoch so two
            # recovering images reconcile by epoch instead of both
            # resurrecting (§11).
            return {"role": "recovering-primary",
                    "epoch": w.objects[name]["epoch"]}
        return {"role": "none"}

    def _op_repl_rejoin(self, name: str, addr: str) -> Dict[str, Any]:
        """Primary side of a restarted node's chain rejoin (§11): run the
        same drain-barrier as a migration — after quiescence there are no
        in-flight versions and no undecided tentatives, so the snapshot
        handed to the rejoiner is exactly the committed state (a live
        object may hold uncommitted in-place writes; snapshotting without
        the drain would bake aborted writes into the new tail)."""
        try:
            shared = self._shared(name)
        except KeyError:
            return {"busy": False}      # not primary here: re-probe
        if shared.node is not self.node:
            return {"busy": False}
        # An idle primary's lease re-arms on first touch (§10): wait the
        # re-ack round out here — every retry would lapse it afresh and
        # bounce busy forever on a quiet chain.
        self._check_grant_blocking(name)
        h = shared.header
        ev = threading.Event()
        with h.lock:
            if name in self._migrating:
                return {"busy": True}
            try:
                self.leases.check_grant(name)
            except LeaseRearming:
                return {"busy": True}   # raced a fresh lapse: retry
            self._migrating[name] = ev
        try:
            if not wait_quiescent(h, timeout=5 * self.leases.ttl):
                return {"busy": True}   # drain never settled: retry later
            payload = pickle.dumps(shared.holder.obj,
                                   protocol=pickle.HIGHEST_PROTOCOL)
            # the rejoiner may have been written off mid-outage: renewal
            # rounds must start reaching it again
            self.leases.departed.discard(addr)
            return self.replication.rejoin_accept(name, addr, payload)
        finally:
            with h.lock:
                self._migrating.pop(name, None)
            ev.set()

    def _op_repl_chain(self, **kw: Any) -> None:
        self.replication.repl_chain(**kw)

    # -- leases + ownership migration (§10) -----------------------------------
    def _op_lease_renew(self, name: str, epoch: int, ttl: float,
                        primary: str) -> None:
        self.leases.on_renew(name, epoch, ttl, primary)

    def _op_lease_ack(self, name: str, epoch: int, ok: bool, cur_epoch: int,
                      node: str) -> None:
        self.leases.on_ack(name, epoch, ok, cur_epoch, node)

    def _op_lease_grant(self, name: str, epoch: int, primary: str) -> bool:
        return self.leases.on_grant(name, epoch, primary)

    def _op_lease_acquire(self, names: List[str]) -> Dict[str, List[str]]:
        """Lease-based takeover (§10): ``ensure_primary``'s server half.

        Refuses *busy* while the current primary's promise is still live
        (it self-fences before the promise lapses — waiting it out is what
        makes the takeover split-brain free); then promotes through the
        replication state machine (which grants the local lease at the new
        epoch) and collects the quorum-of-chain acknowledgement with
        synchronous ``lease_grant`` calls to the remaining followers."""
        busy: List[str] = []
        for n in names:
            if self.has_binding(n):
                continue
            p = self.leases.promised_primary(n)
            if p is None:
                continue
            if self._provably_dead(p):
                # Crash-stop fast path: a *refused* connection means the
                # promised primary's process is gone for good — void the
                # promise instead of waiting out a TTL that can never be
                # exercised again.
                self.leases.void_promise(n, p)
            else:
                busy.append(n)
        if busy:
            return {"promoted": [], "busy": busy}
        res = self.replication.promote(list(names))
        for name in list(res["promoted"]):
            epoch = self.replication.epochs.get(name, 0)
            for addr in self.replication.followers_of(name):
                try:
                    ok = self._peer(addr).call(
                        "lease_grant", name=name, epoch=epoch,
                        primary=self.address)
                except Exception:  # noqa: BLE001 - dead follower: departs
                    self.leases.departed.add(addr)
                    continue
                if not ok:
                    # the follower knows a successor epoch: our promotion
                    # was stale — fence it permanently and report busy so
                    # the caller re-resolves.
                    self.leases.on_ack(name, epoch, ok=False,
                                       cur_epoch=epoch + 1, node=addr)
                    res["promoted"].remove(name)
                    res["busy"].append(name)
                    break
        return res

    def _provably_dead(self, address: str) -> bool:
        """Probe a promised primary before honoring its promise. Only a
        *synchronously refused* connection is proof of death (crash-stop:
        the process is gone and never returns). A ping reply means alive;
        silence, a reset, or any in-flight failure could be a partition —
        then the promise must be waited out (§10 split-brain freedom)."""
        try:
            fut = self._peer(address).call_async("ping")
        except Exception:  # noqa: BLE001 - refused at connect/send: dead
            return True
        try:
            fut.result(timeout=max(2 * self.leases.ttl, 0.05))
        except Exception:  # noqa: BLE001 - ambiguous: treat as alive
            return False
        return False

    def _op_migrate(self, name: str, target: str) -> bool:
        """Forced lease handoff (admin/benchmarks/sweeps): synchronous —
        the reply means the drain-barrier completed one way or the other."""
        return self._do_migrate(name, target)

    def _op_migrate_in(self, name: str, payload: bytes, epoch: int,
                       followers: List[str]) -> bool:
        """Target side of the §10 handoff: bind the shipped state under a
        fresh header, adopt the chain at the shipped epoch, take the lease.
        Idempotent — a retried handoff finds the binding already here."""
        if not self.has_binding(name):
            self.bind_local(name, pickle.loads(payload))
        self.replication.adopt(name, list(followers), epoch, payload)
        self.leases.grant_local(name, epoch)
        return True

    def _op_repl_decision_ack(self, **kw: Any) -> None:
        self.replication.repl_decision_ack(**kw)

    def _op_repl_retire(self, **kw: Any) -> None:
        self.replication.repl_retire(**kw)

    def _op_txn_status(self, txn: str) -> str:
        """The coordinator's decision memo, queried by a promoting
        follower before it dooms an undecided tentative: ``commit`` /
        ``abort`` (decided), ``pending`` (session still live here — the
        decision is coming; retry), or ``none`` (never heard of it, or
        already expired without deciding: dooming is safe)."""
        d = self.replication.decision_of(txn)
        if d is not None:
            return d
        with self._lock:
            live = txn in self._sessions
        return "pending" if live else "none"

    def _op_txn_decision(self, txn: str) -> str:
        """A recovering client (its coordinator died mid-commit) asks a
        follower of the coordinator for the transaction's fate. ``commit``
        additionally re-drives the recorded decision chain so every
        surviving participant terminates; no recorded decision dooms the
        transaction to abort (first-writer-wins). Before dooming, consult
        the coordinator's own ledger if we can still name it (§11): the
        coordinator may have restarted since the client's RPC failed, and
        its replayed WAL is then the only durable copy of a ``commit``
        that was never broadcast — seeding an abort here without looking
        would split the decision across ledgers."""
        if self.replication.decision_of(txn) is None:
            head = self.replication.head_of(txn)
            if head is None and self._recovered is not None:
                # live replica buffers may already have been replaced by
                # the restarted chain's repl_init (which clears them) —
                # our own replayed WAL image still names the coordinator
                for (t, _n), tt in self._recovered.pending.items():
                    if t == txn:
                        head = tt[3]
                        break
            if head and head != self.address and \
                    self.replication._query_head(head, txn) == "commit":
                self.replication.record_decision(txn, "commit")
        d, chain = self.replication.txn_decision(txn)
        if d == "commit" and chain:
            self._drive_decision(txn, chain)
        return d

    # -- introspection / control (tests, benchmarks) -------------------------
    def _op_stats(self) -> Dict[str, Any]:
        with self._lock:
            sessions = len(self._sessions)
        return {"node": self.node_name, "sessions": sessions,
                "rollbacks": list(self.monitor.rollbacks),
                "repl_sent": self.replication.n_sent,
                "leases": self.leases.stats(),
                "ledger": self.replication.ledger_stats(),
                "migrations": self.n_migrations,
                "wal_appends": 0 if self.wal is None else self.wal.n_appends,
                "wal_syncs": 0 if self.wal is None else self.wal.n_syncs,
                "merged_deltas": self.n_merged_deltas,
                "commute_oneways": self.n_commute_oneways,
                "metrics": self.obs_metrics.snapshot()}

    def _op_trace_dump(self, reset: bool = False) -> List[dict]:
        """Pull this node's trace ring (merged-export collection for TCP
        topologies, where the rings live in the server process). Issued
        only by explicit trace exports — never on the bench hot path."""
        evs = self.obs_tracer.events()
        if reset:
            self.obs_tracer.reset()
        return evs



class NodeServer(NodeCore):
    """One registry node served over TCP (the real-wire transport).

    Adds to :class:`NodeCore` everything socket-shaped: the listener and
    per-connection reader threads, the multiplexed framed protocol
    (requests / one-ways / replies / pushes), the grow-on-demand worker
    pool for potentially-blocking ops with the uncontended inline fast
    paths, the non-blocking note pusher, and the real-time session reaper.
    """

    #: Ops that may block (version gates, dispensing 2PL, task joins) or
    #: burn service time (object methods, log application): each gets its
    #: own thread so a parked RPC never stalls the multiplexed connection.
    #: Unknown ops are threaded too — blocking is the conservative guess.
    _INLINE_OPS = frozenset({
        "ping", "list_bindings", "mode_of", "header_state", "header_release",
        "header_terminate", "validate", "release", "terminate",
        "finish_batch", "rollback_batch", "end_txn", "release_version_locks",
        "ensure_checkpoint", "buffer_snapshot", "snap_release", "stats",
        "touch", "clear_holder", "heartbeat", "abandon", "ro_buffer",
        "lw_apply", "repl_init", "repl_apply", "repl_final", "repl_drop",
        "repl_decision", "repl_decision_ack", "repl_retire", "txn_status",
        "lease_renew", "lease_ack", "lease_grant", "migrate_in",
        "chain_probe", "repl_chain", "commute_classes",
    })

    #: wire v3 ships bulk payloads as out-of-band segments.
    _oob = staticmethod(oob)

    def __init__(self, node_name: str = "node0", host: str = "127.0.0.1",
                 port: int = 0, *, registry: Optional[Registry] = None,
                 monitor_timeout: float = 2.0, monitor_poll: float = 0.05,
                 executor_workers: int = 1, wal_dir: Optional[str] = None):
        # durability is strictly opt-in over TCP (--wal-dir): without it
        # the hot path is byte-for-byte the pre-§11 one
        wal = (Wal(FileStorage(os.path.join(wal_dir, f"{node_name}.wal")))
               if wal_dir else None)
        super().__init__(node_name, registry=registry,
                         monitor_timeout=monitor_timeout,
                         monitor_poll=monitor_poll,
                         executor_workers=executor_workers, wal=wal)
        self._pool = _WorkerPool(name=f"op-{node_name}")
        self._note_q: "queue.SimpleQueue" = queue.SimpleQueue()
        threading.Thread(target=self._pusher_loop,
                         name=f"note-pusher-{node_name}",
                         daemon=True).start()
        self._costs: Dict[str, float] = {}      # per-object service-time EWMA
        self._mux: Dict[str, List[_Conn]] = {}          # client_id -> conns
        self._conns: set = set()                        # live connections
        self._stop = threading.Event()
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self.host, self.port = self._listener.getsockname()[:2]
        self._accept_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ #
    # lifecycle                                                           #
    # ------------------------------------------------------------------ #
    @property
    def address(self) -> str:
        return f"{self.host}:{self.port}"

    def start(self) -> "NodeServer":
        self._listener.listen(128)
        self.monitor.start()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"accept-{self.port}", daemon=True)
        self._accept_thread.start()
        threading.Thread(target=self._reaper_loop, name="session-reaper",
                         daemon=True).start()
        if self._recovered is not None and self._recovered.objects:
            # networked half of the restart (§11): probe, rejoin, or
            # resurrect — off the accept path, once the listener is up
            threading.Thread(target=self.rejoin_chains,
                             name=f"rejoin-{self.node_name}",
                             daemon=True).start()
        return self

    def stop(self) -> None:
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        with self._lock:
            conns = list(self._conns)
        for c in conns:   # crash-stop for connected peers (embedded servers)
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        self.monitor.stop()
        self._pool.stop()
        self._note_q.put(None)
        with self._lock:
            peers = list(self._peers.values())
            self._peers.clear()
        for p in peers:
            p.close()
        self.registry.shutdown()

    def serve_forever(self) -> None:
        self.start()
        try:
            while not self._stop.wait(0.2):
                pass
        except KeyboardInterrupt:  # pragma: no cover
            pass
        finally:
            self.stop()

    # ------------------------------------------------------------------ #
    # TCP connection handling (NodeServer)                                 #
    # ------------------------------------------------------------------ #
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                sock, _addr = self._listener.accept()
            except OSError:
                return
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._serve_conn, args=(sock,),
                             name="conn", daemon=True).start()

    def _serve_conn(self, sock: socket.socket) -> None:
        conn = _Conn(sock)
        reader = FrameReader(sock)
        # This thread multiplexes many conversations: tasks woken by the
        # counter advances of its inline ops run on the executor, never
        # here (foreign service time must not stall the link).
        defer_wake_inline()
        with self._lock:
            self._conns.add(sock)
        try:
            while not self._stop.is_set():
                try:
                    req_id, op, kw = reader.recv_msg()
                except (ConnectionClosed, WireError, OSError):
                    break
                if op == "mux_hello":
                    # The mux connection doubles as the §3.4 presence
                    # signal: its drop means this client process died.
                    conn.client_id = kw["client_id"]
                    with self._lock:
                        self._mux.setdefault(conn.client_id, []).append(conn)
                    try:
                        self._send_reply(conn, req_id, OK, None)
                    except (ConnectionClosed, OSError):
                        break
                    continue
                if op in self._CONN_OPS:
                    kw = dict(kw, _conn=conn)   # push notes return this way
                if req_id is None:
                    # One-way: execute inline (FIFO vs later requests on
                    # this connection); failures become deferred-error
                    # notes pushed back to the sender.
                    self._handle_oneway(conn, op, kw)
                elif op in self._INLINE_OPS:
                    if not self._handle_request(conn, req_id, op, kw):
                        break
                elif self._try_fast(conn, req_id, op, kw):
                    pass   # handled inline (uncontended fast path)
                else:
                    self._pool.submit(
                        lambda c=conn, r=req_id, o=op, k=kw:
                        self._handle_timed(c, r, o, k))
        finally:
            with self._lock:
                self._conns.discard(sock)
                last_of_client = False
                if conn.client_id is not None:
                    conns = self._mux.get(conn.client_id, [])
                    if conn in conns:
                        conns.remove(conn)
                    if not conns:
                        self._mux.pop(conn.client_id, None)
                        last_of_client = True
            try:
                sock.close()
            except OSError:
                pass
            if last_of_client:
                self._client_vanished(conn.client_id)

    def _handle_request(self, conn: _Conn, req_id: int, op: str,
                        kw: Dict[str, Any]) -> bool:
        try:
            value = self._dispatch(op, kw)
            status = OK
        except BaseException as e:  # noqa: BLE001 - serialize to peer
            status, value = ERR, encode_error(e)
        try:
            self._send_reply(conn, req_id, status, value)
        except (ConnectionClosed, OSError):
            # The reader (or another worker) will observe the broken socket;
            # make sure it does even if it is parked in recv.
            try:
                conn.sock.close()
            except OSError:
                pass
            return False
        return True

    #: EWMA of per-call service time above which an object's method calls
    #: are dispatched to the worker pool instead of inline on the reader:
    #: genuinely compute-bearing CF methods (the paper models ~3 ms) must
    #: not stall the multiplexed link, but the two thread handoffs of a
    #: pool dispatch dominate the cost of a *quick* method by an order of
    #: magnitude — and for a sub-millisecond method the stall is no worse
    #: than the handoff it replaces. Wall-clock EWMAs on a loaded host
    #: include scheduler noise, so the threshold is deliberately generous.
    INLINE_SLOW_S = 0.002

    def _note_cost(self, name: Optional[str], dt: float) -> None:
        if name is not None:
            old = self._costs.get(name, dt)
            self._costs[name] = 0.7 * old + 0.3 * dt

    def _fast_call(self, conn: _Conn, req_id: int, op: str,
                   kw: Dict[str, Any], weight: int = 1) -> bool:
        """Inline a non-blocking method-bearing op on the reader when the
        object's observed service time says it is quick (optimistically
        inline at first sight; a slow object is learned once and pooled
        thereafter). ``weight`` scales the estimate for batches."""
        name = kw.get("name")
        if self._costs.get(name, 0.0) * weight > self.INLINE_SLOW_S:
            return False
        t0 = time.perf_counter()
        self._handle_request(conn, req_id, op, kw)
        self._note_cost(name, (time.perf_counter() - t0) / max(weight, 1))
        return True

    def _open_ready(self, txn: str, name: str, kind: str) -> bool:
        """True iff the §2.8.2 open would not block: the access (or
        termination) gate is already open for this session's pv.
        (Monotonic counters: once true, stays true.) Errors — no session,
        unknown object — return True: raising is quick, do it inline."""
        try:
            acc = self._acc(txn, name)
        except BaseException:  # noqa: BLE001 - error replies are cheap
            return True
        h = acc.shared.header
        with h.lock:
            done = h.ltv if kind == "termination" else h.lv
            return done >= acc.pv - 1

    #: Pool-dispatched ops whose duration still feeds the service-time
    #: EWMA, so a transiently-inflated estimate (scheduler noise) decays
    #: back under the inline threshold instead of sticking forever.
    #: ``open_call`` is deliberately absent: its pooled duration includes
    #: the gate *wait*, which is contention, not service time.
    _COST_OPS = frozenset({"txn_call", "buf_call", "raw_call",
                           "txn_call_batch"})

    def _handle_timed(self, conn: _Conn, req_id: int, op: str,
                      kw: Dict[str, Any]) -> bool:
        if op not in self._COST_OPS:
            return self._handle_request(conn, req_id, op, kw)
        weight = 1
        if op == "txn_call_batch":
            weight = len(kw.get("calls") or ()) or 1
        t0 = time.perf_counter()
        handled = self._handle_request(conn, req_id, op, kw)
        self._note_cost(kw.get("name"), (time.perf_counter() - t0) / weight)
        return handled

    def _try_fast(self, conn: _Conn, req_id: int, op: str,
                  kw: Dict[str, Any]) -> bool:
        """Uncontended fast paths for normally-threaded ops: when the op
        provably won't block (gates free, commit conditions already open,
        no logs to burn service time on), run it inline on the reader and
        skip two thread handoffs. Contention falls back to the pool.

        Inline work here may include bounded state *snapshots* (§2.7
        buffers, commit checkpoints) — the same class of work the
        ``buffer_snapshot``/``snap_release`` inline ops already do on the
        reader — and, new in v3, *method calls on objects whose measured
        service time is quick* (the EWMA guard of :meth:`_fast_call`):
        the common zero-to-cheap-compute call answers on the reader with
        zero server-side handoffs, while compute-bearing objects keep the
        pool. Gate-blocking opens fall back unless the gate is provably
        open (:meth:`_open_ready`)."""
        if op in ("txn_call", "buf_call", "raw_call"):
            return self._fast_call(conn, req_id, op, kw)
        if op == "txn_call_batch":
            return self._fast_call(conn, req_id, op, kw,
                                   weight=len(kw.get("calls") or ()) or 1)
        if op == "open_call" and not kw.get("entries"):
            if self._open_ready(kw["txn"], kw["name"], kw.get("kind",
                                                             "access")):
                return self._fast_call(conn, req_id, op, kw,
                                       weight=1 + len(kw.get("tail") or ()))
            return False
        if op == "dispense_batch" and not kw.get("chain"):
            try:
                value, status = self._dispatch(op, dict(kw, _nb=True)), OK
            except _WouldBlock:
                return False
            except BaseException as e:  # noqa: BLE001 - serialize to peer
                value, status = encode_error(e), ERR
            try:
                self._send_reply(conn, req_id, status, value)
            except (ConnectionClosed, OSError):
                try:
                    conn.sock.close()
                except OSError:
                    pass
            return True
        if op in ("commit_wave1", "commit_solo"):
            if self._wave1_ready(kw.get("txn"), kw.get("items", ())):
                self._handle_request(conn, req_id, op, kw)
                return True
        return False

    def _wave1_ready(self, txn: str, items: List[tuple]) -> bool:
        """True iff commit steps 2-4 would run without blocking or service
        time: every commit condition already holds and no stray write log
        needs applying. (Monotonic counters: once true, stays true.)"""
        try:
            for name, entries in items:
                if entries:
                    return False
                acc = self._acc(txn, name)
                if acc.oneway_entries:
                    return False   # §12 deltas pending: fold needs a worker
                h = acc.shared.header
                with h.lock:
                    if h.ltv < acc.pv - 1:
                        return False
            return True
        except BaseException:  # noqa: BLE001 - let the pool path raise it
            return False

    # -- sending (replies, pushes, piggybacked notes) ------------------------
    def _send_reply(self, conn: _Conn, req_id: int, status: str,
                    value: Any) -> None:
        with conn.send_lock:
            if conn.pending_out:        # a spilled push frame goes first
                conn.sock.sendall(conn.pending_out)
                conn.pending_out = b""
            notes, conn.notes = conn.notes, []
            try:
                send_msg(conn.sock, (req_id, status, value, notes))
            except (ConnectionClosed, OSError):
                raise
            except Exception as e:  # noqa: BLE001 - unpicklable OK value
                # Keep the connection: report the serialization failure
                # instead of dying (the client would mark the whole server
                # crash-stop dead).
                send_msg(conn.sock, (req_id, ERR, encode_error(e), notes))

    def _queue_note(self, conn: _Conn, note: dict) -> None:
        """Deliver a note on ``conn``: normally a direct *non-blocking*
        push (``MSG_DONTWAIT`` — the queuing thread may be another
        client's reader or the executor, and must never block on this
        client's stalled receive buffer); on a full socket buffer the
        frame's tail spills to the pusher thread, and queued notes also
        ride the next departing reply (piggyback)."""
        spill = False
        with conn.send_lock:
            if conn.pending_out:
                conn.notes.append(note)   # strict frame order: spill more
                spill = True
            else:
                data = wire_frame((None, NOTE, None, [note]))
                try:
                    sent = conn.sock.send(data, socket.MSG_DONTWAIT)
                except (BlockingIOError, InterruptedError):
                    sent = 0
                except OSError:
                    return                # conn dying: client will learn
                if sent != len(data):
                    conn.pending_out = data[sent:]
                    spill = True
        if spill:
            self._note_q.put(conn)

    def _pusher_loop(self) -> None:
        """Flushes spilled push frames and queued notes, blocking only on
        the one connection being flushed (cross-client isolation)."""
        while True:
            conn = self._note_q.get()
            if conn is None:
                return
            try:
                with conn.send_lock:
                    chunks = []
                    if conn.pending_out:
                        chunks.append(conn.pending_out)
                        conn.pending_out = b""
                    notes, conn.notes = conn.notes, []
                    if notes:
                        chunks.append(wire_frame((None, NOTE, None, notes)))
                    if chunks:
                        # spilled tail + queued notes: one vectored send
                        send_frames(conn.sock, chunks)
            except Exception:  # noqa: BLE001 - conn dying: client will learn
                pass

    def _push_target(self, conn: Optional[_Conn],
                     client_id: str) -> Optional[_Conn]:
        """The kickoff's own connection when it belongs to the end client,
        else (chain-forwarded from a peer server) any mux connection the
        end client keeps to this node."""
        if conn is not None and conn.client_id == client_id:
            return conn
        with self._lock:
            conns = self._mux.get(client_id)
            return conns[0] if conns else None

    def _reaper_loop(self) -> None:
        """Expire sessions whose client stopped heartbeating (§3.4).

        Covers clients whose mux connection outlives their heartbeats, and
        — unlike the object-level monitor — also transactions that
        dispensed versions but never *held* anything: their private
        versions must still be advanced past, or every successor wedges on
        the version chain. The staleness scan itself is
        :meth:`NodeCore.reap_stale`, shared with the simulation's
        virtual-clock reaper."""
        while not self._stop.wait(self.monitor.poll_interval):
            self.reap_stale(self._clock())

    # -- control -------------------------------------------------------------
    def _op_shutdown(self) -> None:
        threading.Thread(target=self.stop, daemon=True).start()


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--name", default="node0")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--monitor-timeout", type=float, default=2.0)
    ap.add_argument("--monitor-poll", type=float, default=0.05)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--wal-dir", default=None,
                    help="directory for this node's write-ahead ledger "
                         "(§11); enables crash-restart recovery")
    ap.add_argument("--path", action="append", default=[],
                    help="extra sys.path entries (for unpickling bound "
                         "object classes); repeatable")
    ap.add_argument("--announce", action="store_true",
                    help="print 'LISTENING host:port' once bound")
    args = ap.parse_args(argv)
    for p in args.path:
        if p not in sys.path:
            sys.path.insert(0, p)
    # RPC replies ride thread wakeups (reader -> worker -> reader); the
    # default 5 ms GIL switch interval adds multi-ms convoy latency under
    # load, so run the server with a tighter interval.
    sys.setswitchinterval(0.001)
    _metrics.install_sigusr2()   # live metric dump: kill -USR2 <pid>
    server = NodeServer(args.name, args.host, args.port,
                        monitor_timeout=args.monitor_timeout,
                        monitor_poll=args.monitor_poll,
                        executor_workers=args.workers,
                        wal_dir=args.wal_dir)
    # start (and in particular listen()) BEFORE announcing: the parent
    # connects the moment it reads the line, and must not race the accept
    # loop into a connection refusal.
    server.start()
    if args.announce:
        print(f"LISTENING {server.address}", flush=True)
    try:
        while not server._stop.wait(0.2):
            pass
    except KeyboardInterrupt:  # pragma: no cover
        pass
    finally:
        server.stop()


if __name__ == "__main__":
    main()
