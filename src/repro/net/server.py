"""The node server process (DESIGN.md §3.1).

Hosts a :class:`~repro.core.registry.Registry` with one
:class:`~repro.core.registry.Node` — the real OS-process realization of the
paper's remote host: the ``SharedObject``s, their ``VersionHeader``s, the
per-node :class:`~repro.core.executor.Executor`, and the §3.4
:class:`~repro.core.faults.TransactionMonitor` all live here.

**Delegation boundary.** For every client transaction the server keeps a
*session* — the home-node halves of the client's ``ObjectAccess`` records:
checkpoint (``st``) and read buffer (``buf``) copies, the
modified/holds/released flags the monitor machinery keys off, and the
executor tasks of §2.7 (read-only buffering) and §2.8.4 (last-write log
application). Those tasks are submitted to *this node's* executor gated on
the local version header, so buffering/apply work runs where the data
lives; the client learns only the completion event (``task_join``). Object
state never crosses the wire — not for buffering, not for checkpoints, not
for abort restores.

**Version-lock service.** ``dispense_batch`` implements the server side of
start-time global-order version acquisition (§2.10.2): it acquires this
node's per-object dispensing gates in header-uid order, dispenses private
versions for the whole per-node batch, and *holds* the gates until the
client's ``release_version_locks`` (2PL on version locks across nodes —
one round-trip per node, not per object). Gates are plain ``Lock``s, not
the header ``RLock``s, because they must be releasable from a different
connection thread; dispensing itself still happens under the header lock.

**Failure detection (§3.4).** Sessions are refreshed by client heartbeats;
a client process that dies stops heartbeating (session reaper, detector
timeout) and — faster — drops its *presence* connection (immediate). Either
way ``_expire_session`` performs the paper's self-rollback for everything
the session dispensed on: restore the checkpoint where state was modified
(oldest-restore-wins on the instance epoch), bump the epoch so readers of
the dead transaction's state cascade-abort, and advance ``lv``/``ltv`` past
its private version so survivors' chains unwedge, then commit. Dead
clients' held version-lock gates are force-released the same way. The
object-level :class:`TransactionMonitor` still runs for in-process users of
an embedded server's registry.

Run standalone::

    python -m repro.net.server --name node0 --port 0 --announce

which prints ``LISTENING host:port`` on stdout for the parent to parse
(:mod:`repro.net.spawn` automates this).
"""
from __future__ import annotations

import argparse
import socket
import sys
import threading
import time
from typing import Any, Dict, List, Optional

from repro.core.api import InstanceInvalidated, Mode, method_mode
from repro.core.buffers import CopyBuffer
from repro.core.executor import Task
from repro.core.faults import TransactionMonitor
from repro.core.registry import Registry, SharedObject
from repro.core.versioning import skip_version

from .wire import (ConnectionClosed, OK, WireError, encode_error, recv_msg,
                   send_msg)


class _ServerAccess:
    """Home-node half of one transaction's ``ObjectAccess`` record.

    Field names deliberately mirror ``ObjectAccess`` — the §3.4 monitor's
    ``rollback_object`` reads ``holds_access``/``st``/``modified``/``pv``
    off whatever the object's holder exposes, so sessions plug into the
    existing machinery unchanged.
    """

    __slots__ = ("shared", "pv", "st", "buf", "seen_instance",
                 "holds_access", "released", "modified", "lock")

    def __init__(self, shared: SharedObject, pv: int):
        self.shared = shared
        self.pv = pv
        self.st: Optional[CopyBuffer] = None
        self.buf: Optional[CopyBuffer] = None
        self.seen_instance: Optional[int] = None
        self.holds_access = False
        self.released = False
        self.modified = False
        self.lock = threading.Lock()


class _Session:
    """All server-side state of one client transaction (its txn record).

    Duck-types the transaction for the monitor: ``_accesses`` maps shared
    object → access record, exactly like ``Transaction._accesses``.
    """

    def __init__(self, txn_uid: str, client_id: str):
        self.txn_uid = txn_uid
        self.client_id = client_id
        self._accesses: Dict[SharedObject, _ServerAccess] = {}
        self.tasks: Dict[int, Task] = {}
        self.held_gates: List[threading.Lock] = []
        self.last_contact = time.monotonic()
        self.expired = False      # set by §3.4 expiry; parked tasks no-op
        self._next_task = 0
        self.lock = threading.Lock()

    def new_task_id(self) -> int:
        with self.lock:
            self._next_task += 1
            return self._next_task

    def __repr__(self) -> str:  # pragma: no cover
        return f"_Session({self.txn_uid})"


class NodeServer:
    """One registry node served over TCP."""

    def __init__(self, node_name: str = "node0", host: str = "127.0.0.1",
                 port: int = 0, *, registry: Optional[Registry] = None,
                 monitor_timeout: float = 2.0, monitor_poll: float = 0.05,
                 executor_workers: int = 1):
        self.registry = registry if registry is not None else Registry()
        self.node_name = node_name
        try:
            self.node = self.registry.node(node_name)
        except KeyError:
            self.node = self.registry.add_node(
                node_name, executor_workers=executor_workers)
        self.monitor = TransactionMonitor(
            self.registry, timeout=monitor_timeout, poll_interval=monitor_poll)
        self._sessions: Dict[str, _Session] = {}
        self._gates: Dict[str, threading.Lock] = {}     # per-object dispense gate
        self._presence: Dict[str, socket.socket] = {}   # client_id -> conn
        self._conns: set = set()                        # live connections
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self.host, self.port = self._listener.getsockname()[:2]
        self._accept_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ #
    # lifecycle                                                           #
    # ------------------------------------------------------------------ #
    @property
    def address(self) -> str:
        return f"{self.host}:{self.port}"

    def start(self) -> "NodeServer":
        self._listener.listen(128)
        self.monitor.start()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"accept-{self.port}", daemon=True)
        self._accept_thread.start()
        threading.Thread(target=self._reaper_loop, name="session-reaper",
                         daemon=True).start()
        return self

    def stop(self) -> None:
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        with self._lock:
            conns = list(self._conns)
        for c in conns:   # crash-stop for connected peers (embedded servers)
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        self.monitor.stop()
        self.registry.shutdown()

    def serve_forever(self) -> None:
        self.start()
        try:
            while not self._stop.wait(0.2):
                pass
        except KeyboardInterrupt:  # pragma: no cover
            pass
        finally:
            self.stop()

    # ------------------------------------------------------------------ #
    # connection handling                                                 #
    # ------------------------------------------------------------------ #
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _addr = self._listener.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             name="conn", daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        presence_for: Optional[str] = None
        with self._lock:
            self._conns.add(conn)
        try:
            while not self._stop.is_set():
                try:
                    op, kwargs = recv_msg(conn)
                except (ConnectionClosed, WireError, OSError):
                    break
                if op == "hello":
                    presence_for = kwargs["client_id"]
                    with self._lock:
                        self._presence[presence_for] = conn
                    send_msg(conn, (OK, None))
                    continue
                try:
                    value = self._dispatch(op, kwargs)
                    reply = (OK, value)
                except BaseException as e:  # noqa: BLE001 - serialize to peer
                    reply = encode_error(e)
                try:
                    send_msg(conn, reply)
                except (ConnectionClosed, OSError):
                    break
                except Exception as e:  # noqa: BLE001 - unpicklable OK value
                    # Keep the connection: report the serialization failure
                    # instead of dying (the client would mark the whole
                    # server crash-stop dead).
                    try:
                        send_msg(conn, encode_error(e))
                    except Exception:  # noqa: BLE001
                        break
        finally:
            with self._lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass
            if presence_for is not None:
                with self._lock:
                    is_current = self._presence.get(presence_for) is conn
                if is_current:
                    self._client_vanished(presence_for)

    def _client_vanished(self, client_id: str) -> None:
        """Presence connection dropped: crash-stop the client's sessions."""
        with self._lock:
            self._presence.pop(client_id, None)
            sessions = [s for s in self._sessions.items()
                        if s[1].client_id == client_id]
        for uid, session in sessions:
            self._expire_session(session)
            with self._lock:
                self._sessions.pop(uid, None)

    def _reaper_loop(self) -> None:
        """Expire sessions whose client stopped heartbeating (§3.4).

        Covers clients without a presence connection, and — unlike the
        object-level monitor — also transactions that dispensed versions
        but never *held* anything: their private versions must still be
        advanced past, or every successor wedges on the version chain."""
        while not self._stop.wait(self.monitor.poll_interval):
            now = time.monotonic()
            with self._lock:
                stale = [(uid, s) for uid, s in self._sessions.items()
                         if now - s.last_contact > self.monitor.timeout]
            for uid, session in stale:
                self._expire_session(session)
                with self._lock:
                    self._sessions.pop(uid, None)

    def _expire_session(self, session: _Session) -> None:
        """Crash-stop one client transaction (paper §3.4).

        Performs the complete self-rollback for every object the session
        dispensed on, directly (not via the object-level monitor — a
        handoff raced successor transactions becoming the holder, dropping
        the rollback and leaving the crashed version unterminated): under
        the version lock, restore the checkpoint if the session modified
        live state and nothing newer restored already (oldest-restore-wins
        on the epoch), bump the instance epoch so observers of the dead
        transaction's state cascade-abort, and skip its private version in
        chain order (:func:`~repro.core.versioning.skip_version`) so successors unwedge without
        ever bypassing a live predecessor — this covers held,
        released-but-unterminated, and never-accessed objects alike.
        Version-lock gates the session still holds are force-released.

        ``session.expired`` is set first: the advance below drains waiters,
        including the session's own parked §2.7/§2.8.4 tasks — woken, they
        must no-op rather than apply a dead transaction's buffered writes."""
        session.expired = True
        self._release_gates(session)
        with session.lock:
            accesses = list(session._accesses.items())
        for shared, acc in accesses:
            h = shared.header
            with h.lock:
                # Read access state under the header lock: an lw-apply task
                # holding it is either fully applied (its checkpoint is
                # visible and restored here) or will see `expired` and
                # no-op — never applied-but-unrestored.
                with acc.lock:
                    seen, st, modified = (acc.seen_instance, acc.st,
                                          acc.modified)
                with shared._contact_lock:
                    if shared.holding_txn is session:
                        shared.holding_txn = None
                if st is not None and modified and h.instance == seen:
                    st.restore_into(shared.holder)
                    h.instance += 1
            skip_version(h, acc.pv)
            self.monitor.rollbacks.append(shared.name)

    # ------------------------------------------------------------------ #
    # op dispatch                                                         #
    # ------------------------------------------------------------------ #
    def _dispatch(self, op: str, kw: Dict[str, Any]) -> Any:
        handler = getattr(self, f"_op_{op}", None)
        if handler is None:
            raise WireError(f"unknown op {op!r}")
        return handler(**kw)

    # -- helpers ------------------------------------------------------------
    def _shared(self, name: str) -> SharedObject:
        return self.registry.locate(name)

    def _session(self, txn: str) -> _Session:
        with self._lock:
            session = self._sessions.get(txn)
        if session is None:
            # The session was expired (§3.4 crash-stop suspicion) — an
            # "illusorily crashed" client coming back must abort, exactly
            # like a transaction whose observed instance was invalidated.
            raise InstanceInvalidated(
                f"transaction {txn!r} has no live session on this node "
                f"(rolled back by the failure detector)")
        session.last_contact = time.monotonic()
        return session

    def _acc(self, txn: str, name: str) -> _ServerAccess:
        session = self._session(txn)
        shared = self._shared(name)
        acc = session._accesses.get(shared)
        if acc is None:
            raise InstanceInvalidated(
                f"transaction {txn!r} holds no access on {name!r}")
        return acc

    def _check_valid(self, acc: _ServerAccess) -> None:
        """Per-operation §2.3 validity check, enforced at the home node."""
        with acc.lock:
            seen = acc.seen_instance
        if seen is not None and acc.shared.header.instance != seen:
            raise InstanceInvalidated(
                f"object {acc.shared.name!r} was invalidated by a cascading "
                f"abort (home-node check)")

    def _note_contact(self, session: _Session, acc: _ServerAccess) -> None:
        if acc.holds_access and not acc.released:
            acc.shared.touch(session)
        elif acc.released:
            acc.shared.clear_holder(session)

    def _release_gates(self, session: _Session) -> None:
        with session.lock:
            gates, session.held_gates = session.held_gates, []
        for g in reversed(gates):
            try:
                g.release()
            except RuntimeError:  # pragma: no cover - already released
                pass

    # -- directory ----------------------------------------------------------
    def _op_ping(self) -> Dict[str, Any]:
        return {"node": self.node_name, "time": time.time(),
                "objects": len(self.registry.all_objects())}

    def _op_list_bindings(self) -> Dict[str, Any]:
        return {"node": self.node_name,
                "bindings": sorted(self.registry.all_objects())}

    def _op_bind(self, name: str, obj: Any) -> None:
        self.registry.bind(name, obj, self.node)
        with self._lock:
            self._gates[name] = threading.Lock()

    def _op_mode_of(self, name: str, method: str) -> Mode:
        return method_mode(self._shared(name).holder.obj, method)

    def _op_raw_call(self, name: str, method: str, args: tuple,
                     kwargs: dict) -> Any:
        """Non-transactional direct invocation (Registry-level access)."""
        return self._shared(name).raw_call(method, args, kwargs)

    # -- header surface (RemoteHeader duck type) -----------------------------
    def _op_header_state(self, name: str) -> Dict[str, int]:
        h = self._shared(name).header
        with h.lock:
            return {"gv": h.gv, "lv": h.lv, "ltv": h.ltv,
                    "instance": h.instance}

    def _op_header_wait(self, name: str, kind: str, pv: int,
                        timeout: Optional[float]) -> bool:
        h = self._shared(name).header
        if kind == "termination":
            return h.wait_termination(pv, timeout=timeout)
        return h.wait_access(pv, timeout=timeout)

    def _op_header_release(self, name: str, pv: int) -> None:
        self._shared(name).header.release_to(pv)

    def _op_header_terminate(self, name: str, pv: int) -> None:
        self._shared(name).header.terminate_to(pv)

    # -- start: batched version dispensing (§2.10.2) -------------------------
    def _op_dispense_batch(self, txn: str, client_id: str,
                           names: List[str]) -> Dict[str, int]:
        with self._lock:
            session = self._sessions.get(txn)
            if session is None:
                session = self._sessions[txn] = _Session(txn, client_id)
        objs = [(self._shared(n), n) for n in names]
        objs.sort(key=lambda sn: sn[0].header.uid)   # node-local global order
        pvs: Dict[str, int] = {}
        acquired: List[threading.Lock] = []
        try:
            for shared, name in objs:
                with self._lock:
                    gate = self._gates.setdefault(name, threading.Lock())
                gate.acquire()
                acquired.append(gate)
                with shared.header.lock:
                    pv = shared.header.dispense()
                with session.lock:   # heartbeats iterate _accesses live
                    session._accesses[shared] = _ServerAccess(shared, pv)
                pvs[name] = pv
        except BaseException:
            for g in reversed(acquired):
                g.release()
            raise
        with session.lock:
            session.held_gates.extend(acquired)
        return pvs

    def _op_release_version_locks(self, txn: str) -> None:
        self._release_gates(self._session(txn))

    # -- §2.7 / §2.8.4: asynchronous home-node tasks -------------------------
    def _op_ro_buffer(self, txn: str, name: str, kind: str) -> int:
        session = self._session(txn)
        acc = self._acc(txn, name)
        shared = acc.shared

        def code() -> None:
            if session.expired:
                return        # §3.4: the expiry advanced our version already
            with shared.header.lock:
                inst = shared.header.instance
            with acc.lock:
                acc.seen_instance = inst
                acc.buf = CopyBuffer(shared.holder.obj, inst,
                                     home_node=shared.node)
            shared.header.release_to(acc.pv)
            with acc.lock:
                acc.released = True

        task = self.node.executor.submit(
            shared.header, kind, acc.pv, code,
            name=f"ro-buffer:{name}:{txn}")
        task_id = session.new_task_id()
        session.tasks[task_id] = task
        return task_id

    def _op_lw_apply(self, txn: str, name: str, kind: str,
                     entries: List[tuple]) -> int:
        session = self._session(txn)
        acc = self._acc(txn, name)
        shared = acc.shared

        def code() -> None:
            # The expired check and the apply happen under the header lock,
            # which _expire_session also takes before deciding whether to
            # restore: either we see the expiry and no-op, or the expiry
            # sees our checkpoint (acc.st, written below) and restores it —
            # a dead transaction's log can never slip through unrestored.
            with shared.header.lock:
                if session.expired:
                    return    # §3.4: never apply a dead transaction's log
                inst = shared.header.instance
                st = CopyBuffer(shared.holder.obj, inst,
                                home_node=shared.node)
                obj = shared.holder.obj
                for method, args, kwargs in entries:
                    getattr(obj, method)(*args, **kwargs)
                buf = CopyBuffer(shared.holder.obj, inst,
                                 home_node=shared.node)
                with acc.lock:
                    acc.seen_instance = inst
                    acc.st = st
                    acc.buf = buf
                    acc.modified = True
                    acc.holds_access = True
            shared.header.release_to(acc.pv)
            with acc.lock:
                acc.released = True

        task = self.node.executor.submit(
            shared.header, kind, acc.pv, code,
            name=f"lw-apply:{name}:{txn}")
        task_id = session.new_task_id()
        session.tasks[task_id] = task
        return task_id

    def _op_task_join(self, txn: str, task_id: int) -> Dict[str, Any]:
        session = self._session(txn)
        task = session.tasks[task_id]
        task.join()   # re-raises transactional task errors to the client
        return {}

    # -- synchronous session state operations --------------------------------
    def _op_open_access(self, txn: str, name: str, kind: str,
                        timeout: Optional[float]) -> Dict[str, Any]:
        session = self._session(txn)
        acc = self._acc(txn, name)
        shared = acc.shared
        h = shared.header
        if kind == "termination":
            blocked = h.wait_termination(acc.pv, timeout=timeout)
        else:
            blocked = h.wait_access(acc.pv, timeout=timeout)
        shared.check_reachable()
        with h.lock:
            inst = h.instance
        with acc.lock:
            acc.seen_instance = inst
            acc.st = CopyBuffer(shared.holder.obj, inst, home_node=shared.node)
            acc.holds_access = True
        shared.touch(session)
        return {"blocked": blocked, "instance": inst}

    def _op_txn_call(self, txn: str, name: str, method: str, args: tuple,
                     kwargs: dict, modifies: bool) -> Any:
        session = self._session(txn)
        acc = self._acc(txn, name)
        self._check_valid(acc)
        acc.shared.check_reachable()
        v = getattr(acc.shared.holder.obj, method)(*args, **kwargs)
        if modifies:
            acc.modified = True
        self._note_contact(session, acc)
        return v

    def _op_buf_call(self, txn: str, name: str, method: str, args: tuple,
                     kwargs: dict) -> Any:
        acc = self._acc(txn, name)
        self._check_valid(acc)
        with acc.lock:
            buf = acc.buf
        if buf is None:
            raise RuntimeError(f"no read buffer for {name!r} in {txn!r}")
        return buf.call(method, args, kwargs)

    def _op_apply_log(self, txn: str, name: str,
                      entries: List[tuple]) -> None:
        acc = self._acc(txn, name)
        self._check_valid(acc)
        obj = acc.shared.holder.obj
        for method, args, kwargs in entries:
            getattr(obj, method)(*args, **kwargs)
        acc.modified = True

    def _op_buffer_snapshot(self, txn: str, name: str) -> None:
        acc = self._acc(txn, name)
        shared = acc.shared
        with shared.header.lock:
            inst = shared.header.instance
        with acc.lock:
            acc.buf = CopyBuffer(shared.holder.obj, inst,
                                 home_node=shared.node)

    def _op_ensure_checkpoint(self, txn: str, name: str) -> int:
        acc = self._acc(txn, name)
        shared = acc.shared
        with acc.lock:
            if acc.seen_instance is None:
                with shared.header.lock:
                    acc.seen_instance = shared.header.instance
                acc.st = CopyBuffer(shared.holder.obj, acc.seen_instance,
                                    home_node=shared.node)
            return acc.seen_instance

    def _op_release(self, txn: str, name: str) -> None:
        acc = self._acc(txn, name)
        with acc.lock:
            if acc.released:
                return
        acc.shared.header.release_to(acc.pv)
        with acc.lock:
            acc.released = True

    def _op_wait_termination(self, txn: str, name: str,
                             timeout: Optional[float]) -> bool:
        acc = self._acc(txn, name)
        return acc.shared.header.wait_termination(acc.pv, timeout=timeout)

    def _op_validate(self, txn: str, names: List[str]) -> List[str]:
        """Commit step 4, batched per node: names whose instance moved."""
        bad: List[str] = []
        for name in names:
            acc = self._acc(txn, name)
            with acc.lock:
                seen = acc.seen_instance
            if seen is not None and acc.shared.header.instance != seen:
                bad.append(name)
        return bad

    def _op_rollback(self, txn: str, name: str) -> None:
        acc = self._acc(txn, name)
        h = acc.shared.header
        with acc.lock:
            seen, st, modified = acc.seen_instance, acc.st, acc.modified
        if st is not None and modified:
            with h.lock:
                if h.instance == seen:
                    st.restore_into(acc.shared.holder)
                    h.instance += 1

    def _op_terminate(self, txn: str, name: str) -> None:
        session = self._session(txn)
        acc = self._acc(txn, name)
        acc.shared.header.terminate_to(acc.pv)
        acc.shared.clear_holder(session)
        with acc.lock:
            acc.released = True

    # -- liveness ------------------------------------------------------------
    def _op_touch(self, txn: str, name: str) -> None:
        session = self._session(txn)
        self._shared(name).touch(session)

    def _op_clear_holder(self, txn: str, name: str) -> None:
        session = self._session(txn)
        self._shared(name).clear_holder(session)

    def _op_heartbeat(self, client_id: str, txns: List[str]) -> None:
        now = time.monotonic()
        for uid in txns:
            with self._lock:
                session = self._sessions.get(uid)
            if session is None:
                continue
            session.last_contact = now
            with session.lock:
                accesses = list(session._accesses.items())
            for shared, acc in accesses:
                # Refresh the failure detector for every object this live
                # session still nominally holds — including released-but-
                # unterminated ones (their last_contact would otherwise
                # freeze while the client blocks in commit, and the object
                # monitor would spuriously roll a *live* client back).
                with shared._contact_lock:
                    if shared.holding_txn is session:
                        shared.last_contact = now

    def _op_end_txn(self, txn: str) -> None:
        with self._lock:
            session = self._sessions.pop(txn, None)
        if session is not None:
            self._release_gates(session)

    def _op_abandon(self, txn: str) -> None:
        """Failed-start cleanup: expire the session now (chain-order skip
        of its dispensed versions; nothing was accessed, so no restores)."""
        with self._lock:
            session = self._sessions.pop(txn, None)
        if session is not None:
            self._expire_session(session)

    # -- introspection / control (tests, benchmarks) -------------------------
    def _op_stats(self) -> Dict[str, Any]:
        with self._lock:
            sessions = len(self._sessions)
        return {"node": self.node_name, "sessions": sessions,
                "rollbacks": list(self.monitor.rollbacks)}

    def _op_shutdown(self) -> None:
        threading.Thread(target=self.stop, daemon=True).start()


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--name", default="node0")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--monitor-timeout", type=float, default=2.0)
    ap.add_argument("--monitor-poll", type=float, default=0.05)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--path", action="append", default=[],
                    help="extra sys.path entries (for unpickling bound "
                         "object classes); repeatable")
    ap.add_argument("--announce", action="store_true",
                    help="print 'LISTENING host:port' once bound")
    args = ap.parse_args(argv)
    for p in args.path:
        if p not in sys.path:
            sys.path.insert(0, p)
    server = NodeServer(args.name, args.host, args.port,
                        monitor_timeout=args.monitor_timeout,
                        monitor_poll=args.monitor_poll,
                        executor_workers=args.workers)
    if args.announce:
        print(f"LISTENING {server.address}", flush=True)
    server.serve_forever()


if __name__ == "__main__":
    main()
