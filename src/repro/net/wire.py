"""Length-prefixed binary wire protocol (DESIGN.md §3.1).

Frame format, lowest layer of the transport::

    +----------------+----------------------------+
    | length: u32 BE | payload: `length` bytes    |
    +----------------+----------------------------+

The payload is a pickled message. Messages are tuples:

* request:   ``(op: str, kwargs: dict)`` — one RPC invocation;
* response:  ``(OK, value)`` or ``(ERR, exception)``.

Each pooled connection carries at most one outstanding request (strict
request/response), so no correlation ids are needed; concurrency comes from
the connection pool, and long-blocking RPCs (gate waits, task joins) simply
hold their connection. A zero-length read means the peer closed the socket
— the transport's crash-stop signal (§3.4), surfaced as
:class:`ConnectionClosed` and mapped by the client onto
:class:`~repro.core.api.RemoteObjectFailure`.

Frames are capped at :data:`MAX_FRAME` as a corrupted-peer guard. Pickle
implies the trust model documented in :mod:`repro.net`.
"""
from __future__ import annotations

import pickle
import socket
import struct
from typing import Any, Tuple

_LEN = struct.Struct("!I")
MAX_FRAME = 256 * 1024 * 1024  # corrupted length-word guard

OK = "ok"
ERR = "err"


class WireError(RuntimeError):
    """Malformed traffic (oversized frame, undecodable payload)."""


class ConnectionClosed(ConnectionError):
    """The peer closed the connection (crash-stop detection signal)."""


def encode(msg: Any) -> bytes:
    return pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)


def decode(payload: bytes) -> Any:
    try:
        return pickle.loads(payload)
    except Exception as e:  # noqa: BLE001 - corrupt peer, not our bug
        raise WireError(f"undecodable payload: {e!r}") from e


def send_frame(sock: socket.socket, payload: bytes) -> None:
    if len(payload) > MAX_FRAME:
        raise WireError(f"frame too large: {len(payload)} bytes")
    sock.sendall(_LEN.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        chunk = sock.recv(n)
        if not chunk:
            raise ConnectionClosed("peer closed the connection")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> bytes:
    (length,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if length > MAX_FRAME:
        raise WireError(f"frame too large: {length} bytes")
    return _recv_exact(sock, length) if length else b""


def send_msg(sock: socket.socket, msg: Any) -> None:
    send_frame(sock, encode(msg))


def recv_msg(sock: socket.socket) -> Any:
    return decode(recv_frame(sock))


def encode_error(exc: BaseException) -> Tuple[str, Any]:
    """Build an ``(ERR, exception)`` response, degrading gracefully when the
    exception itself does not survive pickling."""
    try:
        pickle.dumps(exc)
        return (ERR, exc)
    except Exception:  # noqa: BLE001
        return (ERR, RuntimeError(f"{type(exc).__name__}: {exc}"))


def parse_address(address: str) -> Tuple[str, int]:
    """``"host:port"`` → ``(host, port)``."""
    host, _, port = address.rpartition(":")
    return host or "127.0.0.1", int(port)
