"""Length-prefixed binary wire protocol, v2: tagged frames (DESIGN.md §3.1).

Frame format, lowest layer of the transport::

    +----------------+----------------------------+
    | length: u32 BE | payload: `length` bytes    |
    +----------------+----------------------------+

The payload is a pickled message. One multiplexed connection carries many
concurrent conversations, so messages are *tagged* with a request id:

* client → server: ``(req_id, op, kwargs)`` — an RPC invocation. A
  ``req_id`` of ``None`` marks a **one-way** message: the server executes
  the op, sends no reply, and reports failures (if any) as an
  ``oneway_err`` note on the same connection (error deferral — the client
  surfaces it at the transaction's next sync point).
* server → client: ``(req_id, status, value, notes)`` — the reply to the
  request tagged ``req_id``; ``status`` is ``OK`` or ``ERR``. When
  ``req_id`` is ``None`` the message is an unsolicited **push** (``status``
  is ``NOTE``, ``value`` unused). Either way ``notes`` is a (possibly
  empty) list of piggybacked notifications: §2.7/§2.8.4 task completions
  (with the home-node read buffer's state attached when it is small enough
  to ship — the piggyback read protocol) and deferred one-way errors.

Replies are matched to callers by ``req_id`` on the client's reader thread;
out-of-order completion is the normal case (a blocking gate-wait RPC parks
server-side while later quick RPCs on the same socket complete). A reply
whose ``req_id`` is unknown (e.g. arriving after a client-side timeout
abandoned the call) is dropped with a log line, never an error.

A zero-length read means the peer closed the socket — the transport's
crash-stop signal (§3.4), surfaced as :class:`ConnectionClosed` and mapped
by the client onto :class:`~repro.core.api.RemoteObjectFailure`.

Frames are capped at :data:`MAX_FRAME` as a corrupted-peer guard. Pickle
implies the trust model documented in :mod:`repro.net`.
"""
from __future__ import annotations

import pickle
import socket
import struct
from typing import Any, Tuple

_LEN = struct.Struct("!I")
MAX_FRAME = 256 * 1024 * 1024  # corrupted length-word guard

OK = "ok"
ERR = "err"
NOTE = "note"

#: Largest pickled buffer state shipped to the client inside a task-done
#: note (the piggyback read protocol). Larger buffers stay home-node-only
#: and are read through ``buf_call`` RPCs — state never moves in bulk.
PIGGYBACK_MAX = 64 * 1024


class WireError(RuntimeError):
    """Malformed traffic (oversized frame, undecodable payload)."""


class ConnectionClosed(ConnectionError):
    """The peer closed the connection (crash-stop detection signal)."""


def encode(msg: Any) -> bytes:
    return pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)


def decode(payload: bytes) -> Any:
    try:
        return pickle.loads(payload)
    except Exception as e:  # noqa: BLE001 - corrupt peer, not our bug
        raise WireError(f"undecodable payload: {e!r}") from e


def send_frame(sock: socket.socket, payload: bytes) -> None:
    if len(payload) > MAX_FRAME:
        raise WireError(f"frame too large: {len(payload)} bytes")
    sock.sendall(_LEN.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        chunk = sock.recv(n)
        if not chunk:
            raise ConnectionClosed("peer closed the connection")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> bytes:
    (length,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if length > MAX_FRAME:
        raise WireError(f"frame too large: {length} bytes")
    return _recv_exact(sock, length) if length else b""


def frame(msg: Any) -> bytes:
    """The complete on-wire bytes of one message (length prefix included)
    — for senders that need partial-write control (non-blocking pushes)."""
    payload = encode(msg)
    if len(payload) > MAX_FRAME:
        raise WireError(f"frame too large: {len(payload)} bytes")
    return _LEN.pack(len(payload)) + payload


def send_msg(sock: socket.socket, msg: Any) -> None:
    send_frame(sock, encode(msg))


def recv_msg(sock: socket.socket) -> Any:
    return decode(recv_frame(sock))


class FrameReader:
    """Buffered frame reader: one ``recv`` syscall drains as many pipelined
    frames as the kernel has queued, instead of two syscalls per frame.
    On a multiplexed connection carrying many small tagged messages this
    is the dominant syscall reduction. Single-reader use only."""

    __slots__ = ("sock", "_buf")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._buf = bytearray()

    def _fill(self, n: int) -> None:
        while len(self._buf) < n:
            chunk = self.sock.recv(max(65536, n - len(self._buf)))
            if not chunk:
                raise ConnectionClosed("peer closed the connection")
            self._buf += chunk

    def recv_msg(self) -> Any:
        self._fill(_LEN.size)
        (length,) = _LEN.unpack(bytes(self._buf[:_LEN.size]))
        if length > MAX_FRAME:
            raise WireError(f"frame too large: {length} bytes")
        end = _LEN.size + length
        self._fill(end)
        payload = bytes(self._buf[_LEN.size:end])
        del self._buf[:end]
        return decode(payload)


def encode_error(exc: BaseException) -> Any:
    """Return an exception object that survives pickling, degrading to a
    stringified ``RuntimeError`` when the original does not."""
    try:
        pickle.dumps(exc)
        return exc
    except Exception:  # noqa: BLE001
        return RuntimeError(f"{type(exc).__name__}: {exc}")


def parse_address(address: str) -> Tuple[str, int]:
    """``"host:port"`` → ``(host, port)``."""
    host, _, port = address.rpartition(":")
    return host or "127.0.0.1", int(port)
