"""Length-prefixed binary wire protocol, v3: segmented tagged frames
(DESIGN.md §3.1).

Frame format, lowest layer of the transport::

    +----------------+--------------------------------------------------+
    | length: u32 BE | payload: `length` bytes                          |
    +----------------+--------------------------------------------------+

    payload := [nbufs: u8] [pick_len: u32 BE] ([buf_len: u32 BE])*nbufs
               [pickle bytes] ([buffer bytes])*nbufs

The pickle is protocol 5 with **out-of-band buffers**: bulk byte payloads
(piggybacked read-buffer and held-state copies) travel as raw trailing
segments instead of being re-copied into the pickle stream — senders wrap
them with :func:`oob` and the codec is otherwise transparent (receivers
get plain ``bytes`` back). Senders transmit the segment list with one
vectored ``sendmsg`` (:func:`send_msg` / :func:`send_frames`), so neither
the header nor the payload is ever concatenated into a fresh buffer.

One multiplexed connection carries many concurrent conversations, so
messages are *tagged* with a request id:

* client → server: ``(req_id, op, kwargs)`` — an RPC invocation. A
  ``req_id`` of ``None`` marks a **one-way** message: the server executes
  the op, sends no reply, and reports failures (if any) as an
  ``oneway_err`` note on the same connection (error deferral — the client
  surfaces it at the transaction's next sync point).
* server → client: ``(req_id, status, value, notes)`` — the reply to the
  request tagged ``req_id``; ``status`` is ``OK`` or ``ERR``. When
  ``req_id`` is ``None`` the message is an unsolicited **push** (``status``
  is ``NOTE``, ``value`` unused). Either way ``notes`` is a (possibly
  empty) list of piggybacked notifications: §2.7/§2.8.4 task completions
  (with the home-node read buffer's state attached when it is small enough
  to ship — the piggyback read protocol) and deferred one-way errors.

Replies are matched to callers by ``req_id`` — normally by the *caller
itself*, leading its connection's read loop (the leader/follower demux in
``client.py``); out-of-order completion is the normal case (a blocking
gate-wait RPC parks server-side while later quick RPCs on the same socket
complete). A reply whose ``req_id`` is unknown (e.g. arriving after a
client-side timeout abandoned the call) is dropped with a log line, never
an error.

Server-to-server traffic (chain dispensing, the chained commit decision
``commit_wave``/``commit_decide`` hops, and the replication one-ways
``repl_apply``/``repl_final``/``repl_drop``/``repl_decision`` — DESIGN.md
§8) rides the exact same tagged frames: ops are strings, so the protocol
needs no new frame kinds, and FIFO per connection is what the replica
chain's tentative-before-decision ordering argument leans on.

A zero-length read means the peer closed the socket — the transport's
crash-stop signal (§3.4), surfaced as :class:`ConnectionClosed` and mapped
by the client onto :class:`~repro.core.api.RemoteObjectFailure`.

Frames are capped at :data:`MAX_FRAME` as a corrupted-peer guard. Pickle
implies the trust model documented in :mod:`repro.net`.
"""
from __future__ import annotations

import pickle
import socket
import struct
from typing import Any, List, Tuple

_LEN = struct.Struct("!I")
_SEG = struct.Struct("!BI")            # nbufs, pick_len
MAX_FRAME = 256 * 1024 * 1024  # corrupted length-word guard

OK = "ok"
ERR = "err"
NOTE = "note"

#: Largest pickled buffer state shipped to the client inside a task-done
#: note (the piggyback read protocol). Larger buffers stay home-node-only
#: and are read through ``buf_call`` RPCs — state never moves in bulk.
PIGGYBACK_MAX = 64 * 1024

#: Below this size an :func:`oob` payload stays in-band: a trailing
#: segment costs 4 header bytes plus an iovec entry, which only pays for
#: itself once the copy it avoids is non-trivial.
OOB_MIN = 2 * 1024


class WireError(RuntimeError):
    """Malformed traffic (oversized frame, undecodable payload)."""


class ConnectionClosed(ConnectionError):
    """The peer closed the connection (crash-stop detection signal)."""


def oob(data: bytes) -> Any:
    """Mark a bulk byte payload for out-of-band transport: it ships as a
    raw trailing frame segment (no re-copy into the pickle stream) and
    reconstructs as plain ``bytes`` at the receiver. Small payloads stay
    in-band — the segment overhead would outweigh the saved copy."""
    if len(data) >= OOB_MIN:
        return pickle.PickleBuffer(data)
    return data


def encode_segments(msg: Any) -> List[Any]:
    """The complete on-wire representation of one message as a segment
    list ``[header, pickle, *oob_buffers]`` — ready for one vectored
    ``sendmsg``, no concatenation."""
    bufs: List[pickle.PickleBuffer] = []
    try:
        pick = pickle.dumps(msg, protocol=5, buffer_callback=bufs.append)
    except Exception as e:  # noqa: BLE001 - surface as a wire problem
        raise WireError(f"unencodable message: {e!r}") from e
    if not bufs:
        # Small-message fast path (the common tagged frame): one
        # contiguous buffer, so the sender's sendmsg degenerates to a
        # single plain send — no iovec bookkeeping for a 100-byte frame.
        total = _SEG.size + len(pick)
        if total > MAX_FRAME:
            raise WireError(f"frame too large: {total} bytes")
        if len(pick) < 65536:
            return [_LEN.pack(total) + _SEG.pack(0, len(pick)) + pick]
        return [_LEN.pack(total) + _SEG.pack(0, len(pick)), pick]
    views = [b.raw() for b in bufs]
    total = (_SEG.size + _LEN.size * len(views) + len(pick)
             + sum(len(v) for v in views))
    if total > MAX_FRAME:
        raise WireError(f"frame too large: {total} bytes")
    head = (_LEN.pack(total) + _SEG.pack(len(views), len(pick))
            + b"".join(_LEN.pack(len(v)) for v in views))
    return [head, pick, *views]


def decode_payload(view: Any) -> Any:
    """Decode one v3 payload (everything after the length word).
    ``view`` may be any bytes-like; out-of-band segments are materialized
    as independent ``bytes`` (safe to retain after the caller recycles
    its receive buffer)."""
    try:
        nbufs, pick_len = _SEG.unpack_from(view, 0)
        off = _SEG.size
        lens = []
        for _ in range(nbufs):
            (n,) = _LEN.unpack_from(view, off)
            lens.append(n)
            off += _LEN.size
        pick = view[off:off + pick_len]
        off += pick_len
        bufs = []
        for n in lens:
            bufs.append(bytes(view[off:off + n]))
            off += n
        return pickle.loads(pick, buffers=bufs)
    except WireError:
        raise
    except Exception as e:  # noqa: BLE001 - corrupt peer, not our bug
        raise WireError(f"undecodable payload: {e!r}") from e


def sendmsg_all(sock: socket.socket, segments: List[Any]) -> None:
    """``sendall`` semantics over one vectored ``sendmsg``: the normal
    case is a single syscall for the whole segment list; a partial write
    (full socket buffer) resumes from the exact byte."""
    if len(segments) == 1:
        sock.sendall(segments[0])           # common small-frame case
        return
    views = [memoryview(s) for s in segments]
    while views:
        sent = sock.sendmsg(views[:64])     # stay well under IOV_MAX
        while views and sent >= len(views[0]):
            sent -= len(views[0])
            views.pop(0)
        if sent and views:
            views[0] = views[0][sent:]


def send_msg(sock: socket.socket, msg: Any) -> None:
    sendmsg_all(sock, encode_segments(msg))


def send_frames(sock: socket.socket, chunks: List[Any]) -> None:
    """Coalesce several already-framed byte strings (from :func:`frame`)
    into one vectored send — queued outbound frames cost one syscall."""
    sendmsg_all(sock, chunks)


def frame(msg: Any) -> bytes:
    """The complete on-wire bytes of one message (length prefix included)
    as one contiguous buffer — for senders that need partial-write control
    (non-blocking pushes)."""
    return b"".join(bytes(s) if not isinstance(s, bytes) else s
                    for s in encode_segments(msg))


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        chunk = sock.recv(n)
        if not chunk:
            raise ConnectionClosed("peer closed the connection")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> bytes:
    (length,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if length > MAX_FRAME:
        raise WireError(f"frame too large: {length} bytes")
    return _recv_exact(sock, length) if length else b""


def recv_msg(sock: socket.socket) -> Any:
    return decode_payload(recv_frame(sock))


class FrameReader:
    """Buffered frame reader: one ``recv`` syscall drains as many pipelined
    frames as the kernel has queued, instead of two syscalls per frame.
    On a multiplexed connection carrying many small tagged messages this
    is the dominant syscall reduction.

    The receive buffer is a single reusable ``bytearray``; parsing runs
    over memoryviews of it and only out-of-band segments are copied out
    (they outlive the buffer). Single-reader use only — the client's
    leader/follower demux guarantees that by construction (exactly one
    leader per connection), and :meth:`has_frame` lets a departing leader
    drain every already-buffered frame without another syscall.
    """

    __slots__ = ("sock", "_buf")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._buf = bytearray()

    def _fill(self, n: int) -> None:
        while len(self._buf) < n:
            chunk = self.sock.recv(max(65536, n - len(self._buf)))
            if not chunk:
                raise ConnectionClosed("peer closed the connection")
            self._buf += chunk

    def has_frame(self) -> bool:
        """True iff a complete frame is already buffered (zero syscalls)."""
        if len(self._buf) < _LEN.size:
            return False
        (length,) = _LEN.unpack_from(self._buf, 0)
        return len(self._buf) >= _LEN.size + length

    def recv_msg(self) -> Any:
        self._fill(_LEN.size)
        (length,) = _LEN.unpack_from(self._buf, 0)
        if length > MAX_FRAME:
            raise WireError(f"frame too large: {length} bytes")
        end = _LEN.size + length
        self._fill(end)
        view = memoryview(self._buf)
        try:
            msg = decode_payload(view[_LEN.size:end])
        except BaseException:
            # The in-flight exception's traceback pins views of _buf
            # (decode locals): rebuild instead of resizing the exported
            # buffer, which would raise BufferError.
            view.release()
            self._buf = self._buf[end:]
            raise
        view.release()
        del self._buf[:end]
        return msg


def encode_error(exc: BaseException) -> Any:
    """Return an exception object that survives pickling, degrading to a
    stringified ``RuntimeError`` when the original does not."""
    try:
        pickle.dumps(exc)
        return exc
    except Exception:  # noqa: BLE001
        return RuntimeError(f"{type(exc).__name__}: {exc}")


def parse_address(address: str) -> Tuple[str, int]:
    """``"host:port"`` → ``(host, port)``."""
    host, _, port = address.rpartition(":")
    return host or "127.0.0.1", int(port)
