"""Client-side proxies duck-typing the in-process surface (DESIGN.md §3.1).

* :class:`RemoteNode` — stands where :class:`~repro.core.registry.Node`
  stands: one per node server, owning the :class:`~repro.net.client.NodeClient`.
* :class:`RemoteSharedObject` — duck-types
  :class:`~repro.core.registry.SharedObject` (``name``, ``raw_call``,
  ``mode_of``, ``check_reachable``, ``touch``/``clear_holder``, ``header``,
  ``make_access``) so ``Transaction``, ``TransactionMonitor``-facing code,
  and ``txstore`` run unchanged over TCP.
* :class:`RemoteHeader` — the version-header surface
  (``wait_access``/``wait_termination``/``release_to``/``terminate_to``,
  counter reads) as RPCs against the home node's real header.
* :class:`RemoteObjectAccess` — the transport override of
  :class:`~repro.core.transaction.ObjectAccess`, built on the multiplexed
  pipelined connection:

  - §2.7 read-only buffering and §2.8.4 last-write log application are
    **fire-and-forget one-way kickoffs**; the home node pushes a completion
    note (with the read buffer's state when small — the piggyback read
    protocol), so joining the task is usually a local wait and buffered
    reads usually cost zero round trips;
  - early release and single terminates are **one-way notifications**;
    their server-side failures are deferred and surfaced at the
    transaction's next sync point (``raise_deferred``);
  - **operation fusion** (DESIGN.md §3.1 v3): a run of consecutive
    operations on one held object is one ``txn_call_batch`` RPC
    (error-index semantics: prefix applied, suffix not), a run starting
    at first access rides the ``open_call`` RPC (``tail=``), and writes
    past the transaction's last read of the object — single or an
    all-write run — are one-ways with deferred acks;
  - the commit/abort steps issue **per-node batched RPCs asynchronously**
    (``*_async`` → :class:`~repro.net.client.Future`), so one commit wave
    costs one overlapped round trip across all home nodes;
  - genuinely synchronous operations (gate wait + checkpoint, live-state
    method calls, dispensing) remain single awaited RPCs — they are the
    ones whose *results* the operation semantics need before proceeding.

  The write log is recorded locally (pure writes need no synchronization,
  §2.8.4) and ships once, at apply time. Live object state never crosses
  the wire; only read-buffer *snapshots* small enough to ship do.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

from repro.core.api import RemoteObjectFailure, Suprema, warn_deprecated
from repro.core.transaction import Completed, ObjectAccess

from .client import Future, NodeClient
from .leases import LeaseFencedError, ObjectMovedError
from .transport import CLIENT_ID, Transport, load_buf

# The failure-detection grace before promoting a follower (DESIGN.md §8)
# is transport-supplied (`Transport.failover_grace`): one detection period
# >> the maximum one-way latency, so every frame a dead primary queued
# before crashing has landed by promotion time — 50 ms real time on TCP,
# derived from the virtual link latencies under simnet.


class _RemoteBufMarker:
    """Client-side stand-in for a copy buffer that lives on the home node."""

    __slots__ = ()

    def __repr__(self) -> str:  # pragma: no cover
        return "<buffer @ home node>"


_REMOTE_BUF = _RemoteBufMarker()


class RemoteTask:
    """Join handle for an asynchronous task running on the home node.

    The kickoff was pipelined (one-way, or riding the dispense RPC); the
    home node pushes a ``task_done`` note at completion — or delivered it
    on the dispense reply already — so ``join`` normally blocks on a
    *local* wait: zero round trips. How the wait blocks is the transport's
    business (:meth:`~repro.net.transport.Transport.join_task`): the TCP
    client parks on a real event with a ``task_join`` RPC fallback, the
    sim transport yields to the virtual-time scheduler. Either way the
    transport's crash-stop handling fails the wait if the node dies, so
    no joiner can hang on a vanished server.
    """

    __slots__ = ("acc",)

    def __init__(self, acc: "RemoteObjectAccess"):
        self.acc = acc

    def join(self) -> None:
        acc = self.acc
        client = acc.client
        client.raise_deferred(acc.txn_uid)   # sync point: kickoff errors
        wait = client.join_task(acc.txn_uid, acc.shared.name)
        if wait.error is not None:
            raise wait.error
        acc._mark_task_complete(wait.buf)


class RemoteHeader:
    """Version-header surface of a remotely homed object.

    ``lock`` is client-local (API compatibility for ``with header.lock``
    idioms); it provides no cross-client mutual exclusion — real mutual
    exclusion happens on the home node inside each RPC.
    """

    __slots__ = ("shared", "lock")

    def __init__(self, shared: "RemoteSharedObject"):
        self.shared = shared
        self.lock = threading.RLock()

    def _state(self) -> Dict[str, int]:
        return self.shared.client.call("header_state", name=self.shared.name)

    @property
    def gv(self) -> int:
        return self._state()["gv"]

    @property
    def lv(self) -> int:
        return self._state()["lv"]

    @property
    def ltv(self) -> int:
        return self._state()["ltv"]

    @property
    def instance(self) -> int:
        return self._state()["instance"]

    def wait_access(self, pv: int, *, timeout: Optional[float] = None) -> bool:
        return self.shared.client.call(
            "header_wait", name=self.shared.name, kind="access", pv=pv,
            timeout=timeout)

    def wait_termination(self, pv: int, *,
                         timeout: Optional[float] = None) -> bool:
        return self.shared.client.call(
            "header_wait", name=self.shared.name, kind="termination", pv=pv,
            timeout=timeout)

    def release_to(self, pv: int) -> None:
        self.shared.client.call("header_release", name=self.shared.name, pv=pv)

    def terminate_to(self, pv: int) -> None:
        self.shared.client.call("header_terminate", name=self.shared.name,
                                pv=pv)

    def __repr__(self) -> str:  # pragma: no cover
        return f"RemoteHeader({self.shared.name}@{self.shared.node.address})"


class RemoteNode:
    """Client-side handle for one node server process.

    The wire behind it is any :class:`~repro.net.transport.Transport`:
    by default a TCP :class:`NodeClient` is built for ``address``, while a
    pre-built transport (e.g. a simnet :class:`~repro.net.simnet.
    SimTransport`) can be injected via ``client=`` — everything above this
    point (proxies, access records, ``Transaction``) is transport-blind.
    """

    def __init__(self, address: str, client: Optional[Transport] = None,
                 **client_kw: Any):
        self.address = address
        self.client = (client if client is not None
                       else NodeClient(address, **client_kw))
        self.name = address          # refined to the server's node name
        self.alive = True
        self.network_delay = 0.0     # the wire is honest now
        self.registry = None         # set by Registry.connect (federation)

    def reconnect(self) -> bool:
        """Re-dial a node that crash-stopped and restarted at the same
        address (§11 durable identity). Transport-blind: transports
        without a ``reconnect`` (simnet routes by address and survives
        restarts natively) just report their liveness."""
        rc = getattr(self.client, "reconnect", None)
        ok = rc() if rc is not None else bool(self.client.alive)
        if ok:
            self.alive = True
        return ok

    def fetch_bindings(self) -> List["RemoteSharedObject"]:
        info = self.client.call("list_bindings")
        self.name = info["node"]
        followers = info.get("followers", {})
        commutes = info.get("commutes", {})
        out = []
        for n, modes in info["bindings"].items():
            shared = RemoteSharedObject(n, self)
            shared._modes.update(modes)   # no mode_of round trips later
            shared._commutes = dict(commutes.get(n, {}))
            shared.followers = list(followers.get(n, ()))
            out.append(shared)
        return out

    def bind(self, name: str, obj: Any, *args: Any,
             followers: List[str] = (), wal: Any = None,
             lease: Any = None) -> "RemoteSharedObject":
        """Bind ``obj`` under ``name`` on the remote server (ships the
        initial object state once; it lives server-side thereafter).

        The unified publish signature (DESIGN.md §12): keyword-only
        ``followers=()`` configures the object's replica chain (peer node
        addresses, in promotion order — the server seeds each replica and
        forwards committed state along the chain); ``wal``/``lease`` are
        node-level planes on the server, so only their defaults are
        accepted here. The legacy positional ``bind(name, obj, followers)``
        form still works but warns once. When this node was obtained via
        ``Registry.connect``, the new binding is registered there too, so
        ``locate`` sees it without re-connecting."""
        if args:
            warn_deprecated(
                "RemoteNode.bind:positional",
                "RemoteNode.bind(name, obj, followers) with positional "
                "followers is deprecated; use bind(name, obj, "
                "followers=...) — the unified keyword-only publish "
                "signature")
            followers = args[0]
        if wal is not None or lease is not None:
            raise ValueError(
                "wal/lease are configured node-wide on the server; "
                "RemoteNode.bind accepts only their defaults")
        res = self.client.call("bind", name=name, obj=obj,
                               followers=list(followers))
        if isinstance(res, dict) and "modes" in res:
            modes, commutes = res["modes"], res.get("commutes", {})
        else:             # legacy reply shape: the bare modes dict
            modes, commutes = res, {}
        shared = RemoteSharedObject(name, self)
        shared._modes.update(modes or {})
        shared._commutes = dict(commutes or {})
        shared.followers = list(followers)
        if self.registry is not None:
            self.registry.register_remote(shared)
        return shared

    def ping(self) -> Dict[str, Any]:
        return self.client.call("ping")

    def simulate_network(self, from_node: Optional[object]) -> None:
        """No-op: latency is real on this transport."""

    def crash(self) -> None:
        self.alive = False

    def shutdown(self) -> None:
        self.client.close()

    def __repr__(self) -> str:  # pragma: no cover
        return f"RemoteNode({self.name}@{self.address})"


class RemoteSharedObject:
    """Proxy for a shared object homed on a remote node server."""

    def __init__(self, name: str, node: RemoteNode):
        self.name = name
        self.node = node
        self.header = RemoteHeader(self)
        self.failed = False
        self._modes: Dict[str, Any] = {}
        #: {method: commute class} as declared at the home node (§12);
        #: None until fetched (bind/list_bindings ship it for free).
        self._commutes: Optional[Dict[str, str]] = None
        #: replica chain (DESIGN.md §8): peer addresses in promotion order.
        self.followers: List[str] = []

    @property
    def client(self) -> NodeClient:
        return self.node.client

    def make_access(self, txn: object, sup: Suprema) -> "RemoteObjectAccess":
        if getattr(sup, "commutes", None) is not None:
            return RemoteCommuteAccess(txn, self, sup)
        return RemoteObjectAccess(txn, self, sup)

    def mode_of(self, method: str):
        mode = self._modes.get(method)
        if mode is None:
            mode = self.client.call("mode_of", name=self.name, method=method)
            self._modes[method] = mode
        return mode

    def commute_of(self, method: str) -> Optional[str]:
        """Declared commute-class label of ``method``, or None (§12)."""
        return self.commute_classes().get(method)

    def commute_classes(self) -> Dict[str, str]:
        """All ``{method: commute class}`` declarations of this object."""
        if self._commutes is None:
            self._commutes = dict(
                self.client.call("commute_classes", name=self.name))
        return self._commutes

    def check_reachable(self) -> None:
        if self.failed or not self.client.alive or not self.node.alive:
            raise RemoteObjectFailure(
                f"remote object {self.name!r} @ {self.node.address} is "
                f"unreachable")

    def fail(self) -> None:
        self.failed = True

    # -- failover (DESIGN.md §8) ---------------------------------------------
    def _follower_node(self, addr: str) -> RemoteNode:
        reg = self.node.registry
        if reg is not None:
            try:
                node = reg.node(addr)    # pre-connected (sim / federation)
            except KeyError:
                return reg.connect(addr)
            client = getattr(node, "client", None)
            if client is not None and not (node.alive and client.alive):
                node.reconnect()         # §11: same address, reborn process
            return node
        return RemoteNode(addr)

    def follow_move(self, e: ObjectMovedError) -> None:
        """Follow an epoch-fenced redirect (§10 migration): re-point the
        binding at the new primary without reconnecting — the registry
        either already holds a connection to the target (sim/federation)
        or dials one lazily."""
        self.node = self._follower_node(e.target)
        self.failed = False
        if e.followers:
            self.followers = [a for a in e.followers if a != e.target]

    def ensure_primary(self) -> None:
        """Lease acquisition with quorum-of-chain acknowledgement (§10):
        fail over iff the current primary is dead (crash-stop: a node that
        looks dead IS dead) or fenced. Every client — and the decision
        chain's server-side redirect — walks the same configured order, so
        they converge on the same new primary. ``lease_acquire`` reports
        *busy* while the old primary's lease promise is still live (it
        self-fences before the promise lapses — waiting it out is the
        split-brain-freedom condition) or while a buffered tentative's
        coordinator is alive but undecided; both windows are bounded by
        one lease TTL, which the retry budget here outlasts."""
        if not self.failed and self.node.alive and self.client.alive:
            return
        if not self.followers:
            raise RemoteObjectFailure(
                f"remote object {self.name!r} @ {self.node.address} died "
                f"with no replica chain configured")
        # Failure-detection grace: promotion must not outrun frames the
        # dead primary queued before it crashed — in-flight tentatives and
        # decision redirects travel on OTHER links and carry committed
        # state. Crash-stop assumes detection time >> one-way latency (the
        # same assumption the §3.4 expiry reaper makes); sleeping one
        # detection period here makes it explicit. Transport-clocked:
        # virtual under simnet, 50ms real on TCP.
        self.client.sleep(self.client.failover_grace())
        for _attempt in range(90):
            busy_node = None
            for i, addr in enumerate(list(self.followers)):
                try:
                    node = self._follower_node(addr)
                    res = node.client.call("lease_acquire",
                                           names=[self.name])
                except Exception:  # noqa: BLE001 - this follower is dead too
                    continue
                if self.name in res.get("promoted", ()):
                    self.node = node
                    self.failed = False
                    self.followers = self.followers[i + 1:]
                    return
                if self.name in res.get("busy", ()):
                    busy_node = node
                    break   # this follower WILL promote; wait for it
                # unknown here (e.g. its init was lost): try the next one
            if busy_node is None:
                break
            busy_node.client.sleep(0.02)
        raise RemoteObjectFailure(
            f"no follower of {self.name!r} could be promoted")

    def raw_call(self, method: str, args: tuple = (), kwargs: dict = None,
                 from_node: Optional[object] = None) -> Any:
        """Non-transactional direct invocation at the home node (fails
        over to a promoted follower when the primary is dead or fenced,
        follows migration redirects — bounded hops, no reconnect)."""
        for _hop in range(3):
            self.ensure_primary()
            self.check_reachable()
            try:
                return self.client.call("raw_call", name=self.name,
                                        method=method, args=args,
                                        kwargs=kwargs or {})
            except ObjectMovedError as e:
                self.follow_move(e)
            except LeaseFencedError:
                self.fail()    # next hop resolves through the chain
        raise RemoteObjectFailure(
            f"raw_call on {self.name!r} kept redirecting (ownership moving "
            f"faster than the client can chase)")

    def touch(self, txn: object) -> None:
        uid = _txn_uid(txn, self.client.client_id)
        if uid is not None:
            self.client.notify("touch", txn=uid, name=self.name)

    def clear_holder(self, txn: object) -> None:
        uid = _txn_uid(txn, self.client.client_id)
        if uid is not None:
            self.client.notify("clear_holder", txn=uid, name=self.name)

    def __repr__(self) -> str:  # pragma: no cover
        return f"RemoteSharedObject({self.name}@{self.node.address})"


def _txn_uid(txn: object, client_id: str = CLIENT_ID) -> Optional[str]:
    tid = getattr(txn, "id", None)
    if tid is None:
        return None
    inc = getattr(txn, "incarnation", 0)
    # The incarnation makes retries distinct server-side: a late pipelined
    # note or end_txn of a rolled-back incarnation can't touch its successor.
    # ``client_id`` is the transport's process identity — the real process
    # id on TCP, a deterministic simulated-process id under simnet (which is
    # also what lets a fault injection crash ONE simulated client).
    return f"{client_id}#{tid}" if not inc else f"{client_id}#{tid}r{inc}"


class _WireCompletion:
    """Future adapter running a client-side epilogue at await time."""

    __slots__ = ("fut", "epilogue")

    def __init__(self, fut: Future, epilogue=None):
        self.fut = fut
        self.epilogue = epilogue

    def result(self, timeout: Optional[float] = None) -> Any:
        value = self.fut.result(timeout)
        if self.epilogue is not None:
            return self.epilogue(value)
        return value


class RemoteObjectAccess(ObjectAccess):
    """One transaction's access record for a remotely homed object.

    State stays on the home node; this record keeps only control state
    (counters, pv, flags) plus the locally recorded write log. ``st`` is
    never populated client-side — the abort checkpoint is taken and
    restored by the server session. ``buf`` holds either a marker (the
    buffer exists on the home node) or a :class:`_LocalBuf` copy shipped by
    the piggyback read protocol, in which case buffered reads are local.

    ``live_copy`` is the *held-state* piggyback: while this transaction
    holds the access, nothing else can modify the object, so the home node
    ships a (size-gated) state copy on ``open_call`` and refreshes it on
    every modifying call — pure reads in between run locally with zero
    round trips. Staleness is impossible by exclusion; an illusory-crash
    restore (§3.4) bumps the instance epoch and commit validation catches
    it, exactly as for §2.7 buffered reads.
    """

    __slots__ = ("live_copy",)

    def __init__(self, *args: Any, **kwargs: Any):
        super().__init__(*args, **kwargs)
        self.live_copy = None

    # -- identity -----------------------------------------------------------
    @property
    def txn_uid(self) -> str:
        return _txn_uid(self.txn, self.shared.client.client_id)

    @property
    def client(self) -> Transport:
        return self.shared.client

    @property
    def dispense_domain(self) -> tuple:
        # (scheme, address) — a node-level version-lock domain key that
        # sorts identically on every client (global 2PL order, §2.10.2),
        # across transports.
        return (self.shared.client.scheme, self.shared.node.address)

    # -- start (§2.10.2): batched per-node version dispensing ----------------
    def prepare_start(self) -> None:
        """Register liveness (mux hello + heartbeat) for this transaction —
        called *before* any version lock is acquired: connection setup can
        block in a TCP connect and must not stall other transactions
        parked behind our locked headers."""
        self.client.register_txn(self.txn_uid)

    def dispense_many(self, domains: List[List["RemoteObjectAccess"]]) -> None:
        """Chained lock-and-dispense over every remote node of the access
        set in ONE client round trip: the head node dispenses its batch
        (holding its gates), forwards the remainder of the chain to the
        next node in global order, and the aggregated reply returns every
        node's private versions. Acquisition order and hold discipline are
        exactly the sequential 2PL's — only the client bounces between
        nodes are gone, which also shrinks every gate-hold window to a
        server-to-server hop.

        The §2.7 read-only buffering kickoffs ride along for every node:
        tasks whose gate is already open complete during the dispense and
        their results (buffer state included) come back on the same reply
        — the uncontended read-only hot path costs zero extra messages."""
        uid = self.txn_uid
        kind = ("termination"
                if getattr(self.txn, "irrevocable", False) else "access")
        metas = []
        for accs in domains:
            ro_accs = [a for a in accs
                       if a.sup.read_only and a.sup.reads > 0]
            for a in ro_accs:
                a.client.task_wait(uid, a.shared.name)   # pre-register
            metas.append((accs, ro_accs))
        def commute_map(accs):
            return {a.shared.name: a.sup.commutes for a in accs
                    if getattr(a.sup, "commutes", None) is not None}

        head_accs, head_ro = metas[0]
        chain = []
        for accs, ro_accs in metas[1:]:
            ent = {"address": accs[0].shared.node.address,
                   "names": [a.shared.name for a in accs],
                   "ro_names": [a.shared.name for a in ro_accs]}
            cm = commute_map(accs)
            if cm:
                ent["commute"] = cm
            chain.append(ent)
        head_kw = {}
        head_cm = commute_map(head_accs)
        if head_cm:   # non-commute requests stay byte-identical on the wire
            head_kw["commute"] = head_cm
        try:
            res = self.client.call(
                "dispense_batch", txn=uid, client_id=self.client.client_id,
                names=[a.shared.name for a in head_accs],
                ro_names=[a.shared.name for a in head_ro], kind=kind,
                chain=chain,
                affinity=getattr(self.client, "affinity", None) or "",
                **head_kw)
        except ObjectMovedError as e:
            # Drop the start-time liveness registrations on the ORIGINAL
            # transports BEFORE any candidate re-pointing: end_txn must
            # reach the node that opened the session, or its reaper keeps
            # the ghost session alive while our heartbeats keep feeding
            # it — a self-sustaining wedge.
            for accs, _ro in metas:
                accs[0].client.finish_txn(uid)
            # §10 migration redirect: re-point the binding now so the
            # retried transaction dispenses at the new home directly.
            for accs, _ro in metas:
                for a in accs:
                    if a.shared.name == e.name:
                        a.shared.follow_move(e)
            raise
        except LeaseFencedError as e:
            for accs, _ro in metas:
                accs[0].client.finish_txn(uid)
            # the primary self-fenced (partition suspicion): treat it like
            # a dead home — the retry resolves through the follower chain.
            for accs, _ro in metas:
                for a in accs:
                    if a.shared.name == e.name:
                        a.shared.fail()
            raise
        pvs = res["pvs"]
        for accs, ro_accs in metas:
            for a in accs:
                a.pv = pvs[a.shared.name]
            for a in ro_accs:
                note = res["ro"].get(a.shared.name)
                if note is not None:   # completed during the dispense
                    a.client.resolve_task(uid, a.shared.name,
                                          note["error"], note["buf"])
                a.release_task = RemoteTask(a)

    def release_version_locks(self) -> None:
        """One-way: the gates free as soon as the server processes it; no
        reply to wait for (failures defer to the next sync point)."""
        self.client.notify("release_version_locks", txn=self.txn_uid)

    # -- §2.7 / §2.8.4: fire-and-forget kickoffs of home-node tasks ----------
    def spawn_ro_buffer(self, kind: str) -> None:
        self.client.task_wait(self.txn_uid, self.shared.name)  # pre-register
        self.client.notify("ro_buffer", txn=self.txn_uid,
                           name=self.shared.name, kind=kind)
        self.release_task = RemoteTask(self)

    def spawn_lastwrite_apply(self, kind: str) -> None:
        entries = list(self.log.entries)
        self.log.entries.clear()
        self.client.task_wait(self.txn_uid, self.shared.name)  # pre-register
        self.client.notify("lw_apply", txn=self.txn_uid,
                           name=self.shared.name, kind=kind, entries=entries)
        self.release_task = RemoteTask(self)

    def _mark_task_complete(self, buf=None) -> None:
        """A joined home-node task released the object and holds its state;
        ``buf`` carries the piggybacked local read buffer, if shipped."""
        with self.lock:
            self.released = True
            self.buf = buf if buf is not None else _REMOTE_BUF
            if not self.sup.read_only:
                self.holds_access = True
                self.modified = True

    def join_release_task(self) -> None:
        if self.release_task is not None:
            self.release_task.join()

    # -- synchronous state operations (single RPCs) --------------------------
    def open_access(self, kind: str, timeout: Optional[float]) -> bool:
        self.client.raise_deferred(self.txn_uid)
        res = self.client.call("open_access", txn=self.txn_uid,
                               name=self.shared.name, kind=kind,
                               timeout=timeout)
        self.seen_instance = res["instance"]
        self.holds_access = True
        return res["blocked"]

    def open_and_call(self, kind: str, timeout: Optional[float], method: str,
                      args: tuple, kwargs: dict, *, modifies: bool,
                      validity=None):
        """First direct access in one RPC: gate wait + checkpoint + log
        apply + the method call (the in-process path's three steps).
        ``validity`` is ignored: the home node enforces §2.3 inside the
        RPC, as on every other remote operation."""
        self.client.raise_deferred(self.txn_uid)
        entries = list(self.log.entries)
        self.log.entries.clear()
        res = self.client.call("open_call", txn=self.txn_uid,
                               name=self.shared.name, kind=kind,
                               timeout=timeout, entries=entries,
                               method=method, args=args, kwargs=kwargs,
                               modifies=modifies,
                               want_state=self._reads_ahead(0 if modifies
                                                            else 1))
        self.seen_instance = res["instance"]
        self.holds_access = True
        if modifies or entries:
            self.modified = True
        self.live_copy = load_buf(res.get("state"))
        return res["blocked"], res["value"]

    def open_and_call_batch(self, kind: str, timeout: Optional[float],
                            calls: List[tuple]) -> tuple:
        """Operation fusion across the open: gate wait + checkpoint +
        buffered-write apply + the whole FIFO run ``[(method, args,
        kwargs, modifies), ...]`` in ONE RPC — a read-modify-write hop on
        a fresh object costs a single round trip. Returns ``(blocked,
        values, error)`` with ``txn_call_batch`` error-index semantics
        (prefix applied, suffix not)."""
        self.client.raise_deferred(self.txn_uid)
        entries = list(self.log.entries)
        self.log.entries.clear()
        m0, a0, k0, mod0 = calls[0]
        n_reads = sum(1 for c in calls if not c[3])
        res = self.client.call("open_call", txn=self.txn_uid,
                               name=self.shared.name, kind=kind,
                               timeout=timeout, entries=entries,
                               method=m0, args=a0, kwargs=k0, modifies=mod0,
                               want_state=self._reads_ahead(n_reads),
                               tail=[tuple(c) for c in calls[1:]])
        self.seen_instance = res["instance"]
        self.holds_access = True
        values, error = res["values"], res["error"]
        if entries or any(c[3] for c in calls[:len(values)]):
            self.modified = True
        self.live_copy = load_buf(res.get("state"))
        return res["blocked"], values, error

    def raw_call(self, method: str, args: tuple, kwargs: dict, *,
                 modifies: bool) -> Any:
        self.client.raise_deferred(self.txn_uid)
        if not modifies:
            lc = self.live_copy
            if lc is not None:
                # Held-state piggyback: exclusive access means the copy is
                # exact — the pure read costs zero round trips.
                return lc.call(method, args, kwargs)
            return self.client.call("txn_call", txn=self.txn_uid,
                                    name=self.shared.name, method=method,
                                    args=args, kwargs=kwargs, modifies=False)
        res = self.client.call("txn_call", txn=self.txn_uid,
                               name=self.shared.name, method=method,
                               args=args, kwargs=kwargs, modifies=True,
                               want_state=self._reads_ahead(0))
        self.modified = True
        self.live_copy = load_buf(res.get("state"))
        return res["value"]

    def _reads_ahead(self, pending: int) -> bool:
        """Will this transaction still perform pure reads on this object
        (beyond ``pending`` in flight)? If not, a held-state copy has no
        consumer — don't ask the server to serialize one."""
        return self.sup.reads - self.rc - pending > 0

    def write_held(self, method: str, args: tuple, kwargs: dict) -> None:
        """§2.8.4 write on a held object. Past the transaction's last read
        of this object the value-less write needs no synchronous reply: it
        ships as a pipelined one-way — FIFO ahead of every later request
        on the same connection, so any subsequent synchronous operation
        observes it — with server-side failures deferred to the next sync
        point. One round trip saved per trailing write. While reads remain,
        the synchronous path keeps refreshing the held-state copy that
        serves them locally."""
        if self._reads_ahead(0):
            self.raw_call(method, args, kwargs, modifies=True)
            return
        self.client.notify("txn_call", txn=self.txn_uid,
                           name=self.shared.name, method=method, args=args,
                           kwargs=kwargs, modifies=True, want_state=False)
        self.modified = True
        self.live_copy = None   # live state moved without a refresh

    def raw_call_batch(self, calls: List[tuple], *,
                       all_writes: bool = False) -> tuple:
        """Operation fusion: one ``txn_call_batch`` RPC executes the whole
        run FIFO-atomically at the home node (atomic by exclusion — we
        hold the access) and replies with the values plus an error index
        on a mid-run failure, from which the caller restores sequential
        semantics. An all-write run past the last read degenerates to a
        single one-way (no values to wait for; errors deferred)."""
        self.client.raise_deferred(self.txn_uid)
        if all_writes and not self._reads_ahead(0):
            self.client.notify("txn_call_batch", txn=self.txn_uid,
                               name=self.shared.name, calls=list(calls),
                               want_state=False, raise_errors=True)
            self.modified = True
            self.live_copy = None
            return [None] * len(calls), None
        n_reads = sum(1 for c in calls if not c[3])
        any_mod = n_reads < len(calls)
        res = self.client.call(
            "txn_call_batch", txn=self.txn_uid, name=self.shared.name,
            calls=list(calls),
            want_state=any_mod and self._reads_ahead(n_reads))
        values, error = res["values"], res["error"]
        if any(c[3] for c in calls[:len(values)]):
            self.modified = True
            # The reply refreshes the held-state copy or invalidates it
            # (state moved; no refresh shipped on error / by request).
            self.live_copy = load_buf(res.get("state"))
        return values, error

    def buf_call(self, method: str, args: tuple, kwargs: dict) -> Any:
        self.client.raise_deferred(self.txn_uid)
        with self.lock:
            buf = self.buf
        if buf is not None and buf is not _REMOTE_BUF:
            # Piggybacked local copy: zero round trips.
            return buf.call(method, args, kwargs)
        # First read of a home-node buffer: ask for the buffer state to
        # ride along (piggyback), so subsequent reads are local.
        res = self.client.call("buf_call", txn=self.txn_uid,
                               name=self.shared.name, method=method,
                               args=args, kwargs=kwargs, want_buf=True)
        local = load_buf(res["buf"])
        if local is not None:
            with self.lock:
                self.buf = local
        return res["value"]

    def apply_log(self) -> None:
        if len(self.log):
            entries = list(self.log.entries)
            self.log.entries.clear()
            self.client.call("apply_log", txn=self.txn_uid,
                             name=self.shared.name, entries=entries)
            self.modified = True
            self.live_copy = None   # live state moved without a refresh

    def snapshot_buf(self) -> None:
        payload = self.client.call("buffer_snapshot", txn=self.txn_uid,
                                   name=self.shared.name)
        # The reply piggybacks the buffer state when small: trailing reads
        # after the last write/update are then local.
        self.buf = load_buf(payload) or _REMOTE_BUF

    def snapshot_and_release(self) -> None:
        """§2.8.3-4 release point as one pipelined one-way message: the
        writer's hot path never waits for it. With a live held-state copy
        (refreshed by the last modifying reply) the copy *is* the §2.8.3-4
        read buffer — trailing reads are local immediately and the server
        only needs the release. Without one, the buffer stays home and the
        first trailing read fetches it (with piggyback) via ``buf_call``."""
        lc = self.live_copy
        if lc is not None:
            self.client.notify("release", txn=self.txn_uid,
                               name=self.shared.name)
            self.buf = lc
        else:
            self.client.notify("snap_release", txn=self.txn_uid,
                               name=self.shared.name)
            self.buf = _REMOTE_BUF
        self.released = True

    def ensure_checkpoint(self) -> None:
        if self.seen_instance is None:
            self.seen_instance = self.client.call(
                "ensure_checkpoint", txn=self.txn_uid, name=self.shared.name)

    def release(self) -> None:
        """Early release is a one-way notification: successors unblock as
        soon as the server processes it, and this client's hot path never
        waits for the round trip. Errors defer to the next sync point."""
        if not self.released:
            self.client.notify("release", txn=self.txn_uid,
                               name=self.shared.name)
            self.released = True

    def wait_termination(self, timeout: Optional[float]) -> bool:
        self.client.raise_deferred(self.txn_uid)
        return self.client.call("wait_termination", txn=self.txn_uid,
                                name=self.shared.name, timeout=timeout)

    def valid(self) -> bool:
        """Cheap per-operation check: the home node enforces §2.3 on every
        state-touching RPC (raising InstanceInvalidated), so there is
        nothing to evaluate client-side between operations."""
        return True

    def valid_commit(self) -> bool:
        """Authoritative commit-time validation at the home node."""
        bad = self.client.call("validate", txn=self.txn_uid,
                               names=[self.shared.name])
        return not bad

    def valid_commit_batch(self, accs: List["RemoteObjectAccess"]) -> bool:
        """One validation RPC for the whole per-node batch (commit step 4)."""
        return self.valid_commit_batch_async(accs).result()

    # -- commit/abort steps: per-node batched, pipelined RPCs ----------------
    def wait_termination_batch_async(self, accs: List["RemoteObjectAccess"],
                                     timeout: Optional[float],
                                     best_effort: bool = False):
        """Commit step 2 for this node in one RPC, issued without waiting:
        the termination waits of all home nodes overlap."""
        if not best_effort:
            self.client.raise_deferred(self.txn_uid)
        return _WireCompletion(self.client.call_async(
            "wait_termination_batch", txn=self.txn_uid,
            names=[a.shared.name for a in accs], timeout=timeout,
            best_effort=best_effort))

    def commit_wave1_async(self, accs: List["RemoteObjectAccess"],
                           timeout: Optional[float]):
        """Commit steps 2-4 for this node in a single pipelined RPC: wait
        the commit condition, checkpoint/apply/release, validate. The
        waves of different home nodes run concurrently."""
        self.client.raise_deferred(self.txn_uid)
        items = []
        for a in accs:
            entries = list(a.log.entries)
            a.log.entries.clear()
            items.append((a.shared.name, entries))

        def epilogue(res: Dict[str, Any]):
            for a, (_n, entries) in zip(accs, items):
                if a.seen_instance is None:
                    a.seen_instance = -1   # checkpointed server-side
                if entries:
                    a.modified = True
                a.released = True
            return res["blocked"], not res["bad"]

        return _WireCompletion(
            self.client.call_async("commit_wave1", txn=self.txn_uid,
                                   items=items, timeout=timeout), epilogue)

    def valid_commit_batch_async(self, accs: List["RemoteObjectAccess"]):
        fut = self.client.call_async(
            "validate", txn=self.txn_uid,
            names=[a.shared.name for a in accs])
        return _WireCompletion(fut, lambda bad: not bad)

    def finish_batch_async(self, accs: List["RemoteObjectAccess"],
                           best_effort: bool = False):
        """Step 5 (terminate). On the commit path this is a pipelined
        one-way: by the time it is sent the client holds every domain's
        validation verdict — the only input termination needs — so waiting
        for a reply buys nothing. Successors parked on our versions wake
        as soon as the message lands (half a round trip), and a client
        that dies before delivery is exactly the paper's step-5 crash:
        §3.4 expiry converges the session. The abort path
        (``best_effort``) keeps the await: callers of an *aborted*
        transaction may immediately observe server state and must find the
        objects released."""
        uid = self.txn_uid
        names = [a.shared.name for a in accs]
        if best_effort:
            fut = self.client.call_async("finish_batch", txn=uid,
                                         names=names, best_effort=True,
                                         end=True)
        else:
            self.client.notify("finish_batch", txn=uid, names=names,
                               best_effort=True, end=True)
            fut = None
        for a in accs:
            a.released = True
            a.terminated = True
        self.client.mark_session_ended(uid)
        return Completed(None) if fut is None else _WireCompletion(fut)

    def commit_solo_async(self, accs: List["RemoteObjectAccess"],
                          timeout: Optional[float]):
        """Single-domain commit: steps 2-5 in ONE RPC (the validation
        verdict is local to this node, so it can terminate in the same
        unit and drop the session)."""
        self.client.raise_deferred(self.txn_uid)
        uid = self.txn_uid
        items = []
        for a in accs:
            entries = list(a.log.entries)
            a.log.entries.clear()
            items.append((a.shared.name, entries))
        # Commute-restricted accesses (§12) may have deferred dispensing
        # entirely: ship what the server needs to lazily join/dispense at
        # commit time. Absent for ordinary commits (byte-identical wire).
        extra: Dict[str, Any] = {}
        commute = {a.shared.name: a.sup.commutes for a in accs
                   if getattr(a.sup, "commutes", None) is not None}
        if commute:
            extra = {"client_id": self.client.client_id, "commute": commute}
            # Torn-delta fence: when one-way flushes preceded this commit,
            # ship the total delta count — the server refuses to fold a
            # partial set (an illusory-crash expiry may have discarded the
            # flushed prefix before the lazy commit re-created the session).
            counts = {a.shared.name: a.flushed + len(e)
                      for a, (_n, e) in zip(accs, items)
                      if getattr(a, "flushed", 0)}
            if counts:
                extra["commute_counts"] = counts

        def epilogue(res: Dict[str, Any]):
            ok = not res["bad"]
            for a, (_n, entries) in zip(accs, items):
                if a.seen_instance is None:
                    a.seen_instance = -1
                if entries:
                    a.modified = True
                a.released = True
                if ok:
                    a.terminated = True
            if ok:
                self.client.mark_session_ended(uid)
            return res["blocked"], ok

        fut = self.client.call_async("commit_solo", txn=uid, items=items,
                                     timeout=timeout, **extra)

        def recover(err: BaseException):
            """Home node died mid-RPC: same indeterminacy as a dead chain
            coordinator — the commit may have applied and replicated
            before the reply was lost. ``repl_final`` precedes the reply
            on every follower link, so after one detection grace a
            follower's decision ledger is authoritative: a recorded
            commit is reported as success, anything else dooms to abort
            (first-writer-wins, same as the chain path)."""
            if isinstance(err, LeaseFencedError):
                for a in accs:       # fenced primary: re-resolve next txn
                    if a.shared.name == err.name:
                        a.shared.fail()
            self.client.sleep(self.client.failover_grace())
            targets: List[str] = []
            for a in accs:
                for addr in a.shared.followers:
                    if addr not in targets:
                        targets.append(addr)
            for addr in targets:
                try:
                    node = accs[0].shared._follower_node(addr)
                    d = node.client.call("txn_decision", txn=uid)
                except Exception:  # noqa: BLE001 - that follower died too
                    continue
                if d == "commit":
                    for a in accs:
                        if a.seen_instance is None:
                            a.seen_instance = -1
                        a.modified = True
                        a.released = True
                        a.terminated = True
                    self.client.mark_session_ended(uid)
                    return 0, True
                break   # authoritative abort
            raise err

        class _SoloCompletion:
            def result(_self, rpc_timeout: Optional[float] = None):
                try:
                    res = fut.result(rpc_timeout)
                except RemoteObjectFailure as e:
                    return recover(e)
                return epilogue(res)

        return _SoloCompletion()

    def commit_chain_async(self, domains: List[List["RemoteObjectAccess"]],
                           timeout: Optional[float]):
        """Chained multi-domain commit (DESIGN.md §8): ONE RPC to the
        first node in global domain order covers steps 2-5 for EVERY
        remote domain. The coordinator node runs its wave, chains the
        remaining waves server-to-server, makes the commit decision, and
        drives termination down the chain — the client's old N wave RPCs
        plus N terminate one-ways collapse into a single round trip, and
        a client crash after send can no longer strand a partial commit.

        If the coordinator dies mid-call, the decision may still have been
        made and replicated: recovery asks the coordinator's replica
        followers for the transaction's fate (``txn_decision``) before
        concluding abort — a recorded commit is re-driven there and
        reported as success here.
        """
        uid = self.txn_uid
        self.client.raise_deferred(uid)
        per_domain = []
        for accs in domains:
            items = []
            for a in accs:
                entries = list(a.log.entries)
                a.log.entries.clear()
                items.append((a.shared.name, entries))
            per_domain.append((accs, items))
        head_accs, head_items = per_domain[0]
        chain = [{"address": accs[0].shared.node.address,
                  "items": items,
                  "followers": {a.shared.name: list(a.shared.followers)
                                for a in accs if a.shared.followers}}
                 for accs, items in per_domain[1:]]
        fut = self.client.call_async("commit_chain", txn=uid,
                                     items=head_items, timeout=timeout,
                                     chain=chain)

        def mark_terminated() -> None:
            for accs, _items in per_domain:
                for a in accs:
                    a.released = True
                    a.terminated = True
                accs[0].client.mark_session_ended(uid)

        def epilogue(res: Dict[str, Any]):
            for accs, items in per_domain:
                for a, (_n, entries) in zip(accs, items):
                    if a.seen_instance is None:
                        a.seen_instance = -1   # checkpointed server-side
                    if entries:
                        a.modified = True
                    a.released = True
            ok = not res["bad"]
            if ok and res.get("decided"):
                mark_terminated()
            return res["blocked"], ok

        def recover(err: BaseException):
            """Coordinator died mid-RPC: its followers know the fate."""
            # The decision broadcast precedes every effect of the decision
            # but travels on other links: wait one detection grace so a
            # decision the dead coordinator DID replicate has landed
            # before we ask (else we could doom a committed transaction).
            if isinstance(err, LeaseFencedError):
                for accs, _items in per_domain:
                    for a in accs:   # fenced primary: re-resolve next txn
                        if a.shared.name == err.name:
                            a.shared.fail()
            self.client.sleep(self.client.failover_grace())
            targets: List[str] = []
            for a in head_accs:
                for addr in a.shared.followers:
                    if addr not in targets:
                        targets.append(addr)
            for addr in targets:
                try:
                    node = head_accs[0].shared._follower_node(addr)
                    d = node.client.call("txn_decision", txn=uid)
                except Exception:  # noqa: BLE001 - that follower died too
                    continue
                if d == "commit":
                    mark_terminated()
                    return 0, True
                break   # authoritative abort (first-writer-wins doom)
            raise err

        class _ChainCompletion:
            def result(_self, rpc_timeout: Optional[float] = None):
                try:
                    res = fut.result(rpc_timeout)
                except RemoteObjectFailure as e:
                    return recover(e)
                return epilogue(res)

        return _ChainCompletion()

    def rollback_batch_async(self, accs: List["RemoteObjectAccess"]):
        return _WireCompletion(self.client.call_async(
            "rollback_batch", txn=self.txn_uid,
            names=[a.shared.name for a in accs]))

    def raise_deferred(self) -> None:
        """Sync point for this access's pipelined one-way operations."""
        self.client.raise_deferred(self.txn_uid)

    def abandon(self) -> None:
        """Failed-start cleanup: the home node skips this transaction's
        dispensed versions in chain order and drops the session."""
        self.client.call("abandon", txn=self.txn_uid)

    def rollback(self) -> None:
        self.client.call("rollback", txn=self.txn_uid, name=self.shared.name)

    def terminate(self) -> None:
        self.client.notify("terminate", txn=self.txn_uid,
                           name=self.shared.name)
        self.terminated = True

    def note_contact(self) -> None:
        """No-op: every session RPC refreshes the server-side detector, and
        the client heartbeat covers idle stretches."""

    def check_reachable(self) -> None:
        self.shared.check_reachable()

    def finish_session(self) -> None:
        self.client.finish_txn(self.txn_uid)


#: Client-side delta buffer high-water mark (§12): a commute-restricted
#: access ships its buffered deltas as one ``commute_delta`` one-way per
#: this many entries; the remainder rides the commit RPC. Low enough to
#: bound client memory on long hot-key transactions, high enough that
#: short ones (< DELTA_FLUSH deltas) cost zero extra messages.
DELTA_FLUSH = 8


class RemoteCommuteAccess(RemoteObjectAccess):
    """Commute-restricted access record for a remotely homed object (§12).

    The transaction promised to touch the object only through methods of
    one commuting class, so nothing here needs synchronization:

    - **deferred dispensing**: when the whole access set is commute-only
      on one remote node, ``dispense_for`` skips the dispense RPC entirely
      (``defer_start``); the home node lazily joins the object's commute
      group — or falls back to an exact version — at the first delta
      one-way or at commit, whichever arrives first;
    - **mergeable deltas**: invocations are recorded locally (a §2.8.4
      log) and ship as pipelined ``commute_delta`` one-ways past
      ``DELTA_FLUSH`` entries — FIFO on the same mux connection as the
      commit RPC that follows, so the server always folds a complete
      delta set. One-ways are only used on the deferred (single-domain)
      path: a multi-domain commit forwards its items server-to-server,
      which would race client-issued one-ways;
    - whether the server *actually* joined a commute group or fell back
      to exact dispensing (snap-back, §12) is invisible here: commute
      methods are write-only, so there is no value to return either way.
    """

    __slots__ = ("deferred_start", "flushed")

    #: dispense_for may skip the dispense RPC for an all-commute
    #: single-remote-domain access set (§12 deferred start).
    can_defer_start = True

    def __init__(self, *args: Any, **kwargs: Any):
        super().__init__(*args, **kwargs)
        self.deferred_start = False
        self.flushed = 0

    @property
    def commute_cls(self) -> str:
        return self.sup.commutes

    def defer_start(self) -> None:
        """Skip dispensing: the home node joins/dispenses lazily."""
        self.deferred_start = True
        self.pv = 0

    def record_commute(self, method: str, args: tuple, kwargs: dict) -> None:
        self.log.record(method, args, kwargs)
        if self.deferred_start and len(self.log.entries) >= DELTA_FLUSH:
            entries = list(self.log.entries)
            self.log.entries.clear()
            self.flushed += len(entries)
            self.client.notify(
                "commute_delta", txn=self.txn_uid,
                client_id=self.client.client_id, name=self.shared.name,
                cls=self.commute_cls, entries=entries)
            self.modified = True
