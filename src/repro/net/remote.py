"""Client-side proxies duck-typing the in-process surface (DESIGN.md §3.1).

* :class:`RemoteNode` — stands where :class:`~repro.core.registry.Node`
  stands: one per node server, owning the :class:`~repro.net.client.NodeClient`.
* :class:`RemoteSharedObject` — duck-types
  :class:`~repro.core.registry.SharedObject` (``name``, ``raw_call``,
  ``mode_of``, ``check_reachable``, ``touch``/``clear_holder``, ``header``,
  ``make_access``) so ``Transaction``, ``TransactionMonitor``-facing code,
  and ``txstore`` run unchanged over TCP.
* :class:`RemoteHeader` — the version-header surface
  (``wait_access``/``wait_termination``/``release_to``/``terminate_to``,
  counter reads) as RPCs against the home node's real header.
* :class:`RemoteObjectAccess` — the transport override of
  :class:`~repro.core.transaction.ObjectAccess`: every state operation
  becomes one RPC executed on the home node; the write log is recorded
  locally (pure writes need no synchronization, §2.8.4) and ships once,
  at apply time. Object state never crosses the wire.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

from repro.core.api import RemoteObjectFailure, Suprema
from repro.core.transaction import ObjectAccess

from .client import CLIENT_ID, NodeClient


class _RemoteBufMarker:
    """Client-side stand-in for a copy buffer that lives on the home node."""

    __slots__ = ()

    def __repr__(self) -> str:  # pragma: no cover
        return "<buffer @ home node>"


_REMOTE_BUF = _RemoteBufMarker()


class RemoteTask:
    """Join handle for an asynchronous task running on the home node.

    ``join`` blocks in a single RPC until the server-side executor task
    completes; the result (or transactional error) is cached so trailing
    buffered reads don't re-join over the wire."""

    __slots__ = ("acc", "task_id", "_done", "_error", "_lock")

    def __init__(self, acc: "RemoteObjectAccess", task_id: int):
        self.acc = acc
        self.task_id = task_id
        self._done = False
        self._error: Optional[BaseException] = None
        self._lock = threading.Lock()

    def join(self) -> None:
        with self._lock:
            if not self._done:
                try:
                    self.acc.client.call(
                        "task_join", txn=self.acc.txn_uid, task_id=self.task_id)
                except BaseException as e:  # noqa: BLE001 - cache and re-raise
                    self._error = e
                else:
                    self.acc._mark_task_complete()
                self._done = True
        if self._error is not None:
            raise self._error


class RemoteHeader:
    """Version-header surface of a remotely homed object.

    ``lock`` is client-local (API compatibility for ``with header.lock``
    idioms); it provides no cross-client mutual exclusion — real mutual
    exclusion happens on the home node inside each RPC.
    """

    __slots__ = ("shared", "lock")

    def __init__(self, shared: "RemoteSharedObject"):
        self.shared = shared
        self.lock = threading.RLock()

    def _state(self) -> Dict[str, int]:
        return self.shared.client.call("header_state", name=self.shared.name)

    @property
    def gv(self) -> int:
        return self._state()["gv"]

    @property
    def lv(self) -> int:
        return self._state()["lv"]

    @property
    def ltv(self) -> int:
        return self._state()["ltv"]

    @property
    def instance(self) -> int:
        return self._state()["instance"]

    def wait_access(self, pv: int, *, timeout: Optional[float] = None) -> bool:
        return self.shared.client.call(
            "header_wait", name=self.shared.name, kind="access", pv=pv,
            timeout=timeout)

    def wait_termination(self, pv: int, *,
                         timeout: Optional[float] = None) -> bool:
        return self.shared.client.call(
            "header_wait", name=self.shared.name, kind="termination", pv=pv,
            timeout=timeout)

    def release_to(self, pv: int) -> None:
        self.shared.client.call("header_release", name=self.shared.name, pv=pv)

    def terminate_to(self, pv: int) -> None:
        self.shared.client.call("header_terminate", name=self.shared.name,
                                pv=pv)

    def __repr__(self) -> str:  # pragma: no cover
        return f"RemoteHeader({self.shared.name}@{self.shared.node.address})"


class RemoteNode:
    """Client-side handle for one node server process."""

    def __init__(self, address: str, **client_kw: Any):
        self.address = address
        self.client = NodeClient(address, **client_kw)
        self.name = address          # refined to the server's node name
        self.alive = True
        self.network_delay = 0.0     # the wire is honest now
        self.registry = None         # set by Registry.connect (federation)

    def fetch_bindings(self) -> List["RemoteSharedObject"]:
        info = self.client.call("list_bindings")
        self.name = info["node"]
        return [RemoteSharedObject(n, self) for n in info["bindings"]]

    def bind(self, name: str, obj: Any) -> "RemoteSharedObject":
        """Bind ``obj`` under ``name`` on the remote server (ships the
        initial object state once; it lives server-side thereafter). When
        this node was obtained via ``Registry.connect``, the new binding is
        registered there too, so ``locate`` sees it without re-connecting."""
        self.client.call("bind", name=name, obj=obj)
        shared = RemoteSharedObject(name, self)
        if self.registry is not None:
            self.registry.register_remote(shared)
        return shared

    def ping(self) -> Dict[str, Any]:
        return self.client.call("ping")

    def simulate_network(self, from_node: Optional[object]) -> None:
        """No-op: latency is real on this transport."""

    def crash(self) -> None:
        self.alive = False

    def shutdown(self) -> None:
        self.client.close()

    def __repr__(self) -> str:  # pragma: no cover
        return f"RemoteNode({self.name}@{self.address})"


class RemoteSharedObject:
    """Proxy for a shared object homed on a remote node server."""

    def __init__(self, name: str, node: RemoteNode):
        self.name = name
        self.node = node
        self.header = RemoteHeader(self)
        self.failed = False
        self._modes: Dict[str, Any] = {}

    @property
    def client(self) -> NodeClient:
        return self.node.client

    def make_access(self, txn: object, sup: Suprema) -> "RemoteObjectAccess":
        return RemoteObjectAccess(txn, self, sup)

    def mode_of(self, method: str):
        mode = self._modes.get(method)
        if mode is None:
            mode = self.client.call("mode_of", name=self.name, method=method)
            self._modes[method] = mode
        return mode

    def check_reachable(self) -> None:
        if self.failed or not self.client.alive or not self.node.alive:
            raise RemoteObjectFailure(
                f"remote object {self.name!r} @ {self.node.address} is "
                f"unreachable")

    def fail(self) -> None:
        self.failed = True

    def raw_call(self, method: str, args: tuple = (), kwargs: dict = None,
                 from_node: Optional[object] = None) -> Any:
        """Non-transactional direct invocation at the home node."""
        self.check_reachable()
        return self.client.call("raw_call", name=self.name, method=method,
                                args=args, kwargs=kwargs or {})

    def touch(self, txn: object) -> None:
        uid = _txn_uid(txn)
        if uid is not None:
            self.client.call("touch", txn=uid, name=self.name)

    def clear_holder(self, txn: object) -> None:
        uid = _txn_uid(txn)
        if uid is not None:
            self.client.call("clear_holder", txn=uid, name=self.name)

    def __repr__(self) -> str:  # pragma: no cover
        return f"RemoteSharedObject({self.name}@{self.node.address})"


def _txn_uid(txn: object) -> Optional[str]:
    tid = getattr(txn, "id", None)
    return None if tid is None else f"{CLIENT_ID}#{tid}"


class RemoteObjectAccess(ObjectAccess):
    """One transaction's access record for a remotely homed object.

    State stays on the home node; this record keeps only control state
    (counters, pv, flags) plus the locally recorded write log. ``st`` is
    never populated client-side — the abort checkpoint is taken and
    restored by the server session; ``buf`` holds a marker object when the
    home-node read buffer exists.
    """

    __slots__ = ()

    # -- identity -----------------------------------------------------------
    @property
    def txn_uid(self) -> str:
        return _txn_uid(self.txn)

    @property
    def client(self) -> NodeClient:
        return self.shared.client

    @property
    def dispense_domain(self) -> tuple:
        return ("tcp", self.shared.node.address)

    # -- start (§2.10.2): batched per-node version dispensing ----------------
    def prepare_start(self) -> None:
        """Register liveness (presence + heartbeat) for this transaction —
        called *before* any version lock is acquired: presence setup can
        block in a TCP connect and must not stall other transactions
        parked behind our locked headers."""
        self.client.register_txn(self.txn_uid)

    def dispense_batch(self, accs: List["RemoteObjectAccess"]) -> None:
        """Lock-and-dispense for every access of this node, one round trip.
        The server holds the version-lock gates until
        :meth:`release_version_locks`."""
        pvs = self.client.call(
            "dispense_batch", txn=self.txn_uid, client_id=CLIENT_ID,
            names=[a.shared.name for a in accs])
        for a in accs:
            a.pv = pvs[a.shared.name]

    def release_version_locks(self) -> None:
        self.client.call("release_version_locks", txn=self.txn_uid)

    # -- §2.7 / §2.8.4: tasks run on the home node ---------------------------
    def spawn_ro_buffer(self, kind: str) -> None:
        task_id = self.client.call("ro_buffer", txn=self.txn_uid,
                                   name=self.shared.name, kind=kind)
        self.release_task = RemoteTask(self, task_id)

    def spawn_lastwrite_apply(self, kind: str) -> None:
        entries = list(self.log.entries)
        self.log.entries.clear()
        task_id = self.client.call("lw_apply", txn=self.txn_uid,
                                   name=self.shared.name, kind=kind,
                                   entries=entries)
        self.release_task = RemoteTask(self, task_id)

    def _mark_task_complete(self) -> None:
        """A joined home-node task released the object and holds its state."""
        with self.lock:
            self.released = True
            self.buf = _REMOTE_BUF
            if not self.sup.read_only:
                self.holds_access = True
                self.modified = True

    def join_release_task(self) -> None:
        if self.release_task is not None:
            self.release_task.join()

    # -- synchronous state operations (single RPCs) --------------------------
    def open_access(self, kind: str, timeout: Optional[float]) -> bool:
        res = self.client.call("open_access", txn=self.txn_uid,
                               name=self.shared.name, kind=kind,
                               timeout=timeout)
        self.seen_instance = res["instance"]
        self.holds_access = True
        return res["blocked"]

    def raw_call(self, method: str, args: tuple, kwargs: dict, *,
                 modifies: bool) -> Any:
        v = self.client.call("txn_call", txn=self.txn_uid,
                             name=self.shared.name, method=method, args=args,
                             kwargs=kwargs, modifies=modifies)
        if modifies:
            self.modified = True
        return v

    def buf_call(self, method: str, args: tuple, kwargs: dict) -> Any:
        return self.client.call("buf_call", txn=self.txn_uid,
                                name=self.shared.name, method=method,
                                args=args, kwargs=kwargs)

    def apply_log(self) -> None:
        if len(self.log):
            entries = list(self.log.entries)
            self.log.entries.clear()
            self.client.call("apply_log", txn=self.txn_uid,
                             name=self.shared.name, entries=entries)
            self.modified = True

    def snapshot_buf(self) -> None:
        self.client.call("buffer_snapshot", txn=self.txn_uid,
                         name=self.shared.name)
        self.buf = _REMOTE_BUF

    def ensure_checkpoint(self) -> None:
        if self.seen_instance is None:
            self.seen_instance = self.client.call(
                "ensure_checkpoint", txn=self.txn_uid, name=self.shared.name)

    def release(self) -> None:
        if not self.released:
            self.client.call("release", txn=self.txn_uid,
                             name=self.shared.name)
            self.released = True

    def wait_termination(self, timeout: Optional[float]) -> bool:
        return self.client.call("wait_termination", txn=self.txn_uid,
                                name=self.shared.name, timeout=timeout)

    def valid(self) -> bool:
        """Cheap per-operation check: the home node enforces §2.3 on every
        state-touching RPC (raising InstanceInvalidated), so there is
        nothing to evaluate client-side between operations."""
        return True

    def valid_commit(self) -> bool:
        """Authoritative commit-time validation at the home node."""
        bad = self.client.call("validate", txn=self.txn_uid,
                               names=[self.shared.name])
        return not bad

    def valid_commit_batch(self, accs: List["RemoteObjectAccess"]) -> bool:
        """One validation RPC for the whole per-node batch (commit step 4)."""
        bad = self.client.call("validate", txn=self.txn_uid,
                               names=[a.shared.name for a in accs])
        return not bad

    def abandon(self) -> None:
        """Failed-start cleanup: the home node skips this transaction's
        dispensed versions in chain order and drops the session."""
        self.client.call("abandon", txn=self.txn_uid)

    def rollback(self) -> None:
        self.client.call("rollback", txn=self.txn_uid, name=self.shared.name)

    def terminate(self) -> None:
        self.client.call("terminate", txn=self.txn_uid, name=self.shared.name)
        self.terminated = True

    def note_contact(self) -> None:
        """No-op: every session RPC refreshes the server-side detector, and
        the client heartbeat covers idle stretches."""

    def check_reachable(self) -> None:
        self.shared.check_reachable()

    def finish_session(self) -> None:
        self.client.finish_txn(self.txn_uid)
