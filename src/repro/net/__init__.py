"""``repro.net`` — a real wire for OptSVA-CF (DESIGN.md §3.1).

Atomic RMI 2 runs transactions against objects *homed* on remote JVMs over
Java RMI; this package is the reproduction's analogue: registry nodes become
real OS processes reachable over TCP, and the control-flow (CF) model's
delegation becomes literal — §2.7 read-only buffering, §2.8.4 last-write log
application, checkpointing, and abort restores all execute *on the home
node*; only versions, instance epochs, and method return values cross the
wire. Object state never moves for a buffered write.

Modules:

* :mod:`repro.net.wire`   — length-prefixed binary framing + the tagged
  message codec (requests, one-way messages, replies with piggybacked
  notes, server pushes);
* :mod:`repro.net.client` — multiplexed pipelined RPC client
  (``call_async`` futures, fire-and-forget ``notify``, deferred one-way
  errors, pushed task notes) with liveness riding the same link;
* :mod:`repro.net.server` — the node server process: hosts
  ``SharedObject``/``VersionHeader``/``Executor`` plus per-transaction
  *sessions* (whose access records subclass ``ObjectAccess``) and the §3.4
  :class:`~repro.core.faults.TransactionMonitor`; concurrent per-connection
  dispatch with reply tagging and completion pushes;
* :mod:`repro.net.remote` — ``RemoteNode``/``RemoteSharedObject``/
  ``RemoteObjectAccess`` duck-typing the in-process surface so
  ``Transaction``, ``TransactionMonitor``, and ``txstore`` run unchanged
  over either transport;
* :mod:`repro.net.spawn`  — subprocess helpers used by benchmarks, tests,
  and the distributed quickstart;
* :mod:`repro.net.transport` — the narrow client-side ``Transport``
  interface both wires implement (plus the shared deferred-error /
  task-note bookkeeping);
* :mod:`repro.net.simnet` — the deterministic simulation transport
  (DESIGN.md §7): every node in one process under a virtual clock, a
  seeded scheduler owning delivery order/latency/faults, byte-replayable
  schedule traces.

Trust model: frames carry pickles, so a node server must only be exposed to
trusted peers (localhost or a private cluster network) — exactly the
deployment model of Java RMI serialization in the source system.
"""
from repro.core.api import warn_deprecated

from .client import NodeClient
from .remote import RemoteNode, RemoteObjectAccess, RemoteSharedObject
from .server import NodeCore, NodeServer
from .simnet import SimNet, SimNode, SimTransport, build_simnet
from .spawn import ServerHandle
from .transport import CLIENT_ID, Transport
from .wire import ConnectionClosed, WireError


def __getattr__(name: str):
    # Legacy public import path (pre-§12 API): kept working, warns once,
    # points at the canonical surface.
    if name == "spawn_server":
        warn_deprecated(
            "import:repro.net.spawn_server",
            "importing spawn_server from repro.net is deprecated; use "
            "repro.dtm.spawn_server (the unified public API surface)")
        from .spawn import spawn_server
        return spawn_server
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "CLIENT_ID", "NodeClient", "RemoteNode", "RemoteObjectAccess",
    "RemoteSharedObject", "NodeCore", "NodeServer", "ServerHandle",
    "SimNet", "SimNode", "SimTransport", "Transport", "build_simnet",
    "spawn_server", "ConnectionClosed", "WireError",
]
