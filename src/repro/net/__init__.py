"""``repro.net`` — a real wire for OptSVA-CF (DESIGN.md §3.1).

Atomic RMI 2 runs transactions against objects *homed* on remote JVMs over
Java RMI; this package is the reproduction's analogue: registry nodes become
real OS processes reachable over TCP, and the control-flow (CF) model's
delegation becomes literal — §2.7 read-only buffering, §2.8.4 last-write log
application, checkpointing, and abort restores all execute *on the home
node*; only versions, instance epochs, and method return values cross the
wire. Object state never moves for a buffered write.

Modules:

* :mod:`repro.net.wire`   — length-prefixed binary framing + message codec;
* :mod:`repro.net.client` — connection-pooled RPC client with the liveness
  heartbeat (one per client process per server);
* :mod:`repro.net.server` — the node server process: hosts
  ``SharedObject``/``VersionHeader``/``Executor`` plus per-transaction
  *sessions* (the server-side halves of ``ObjectAccess``) and the §3.4
  :class:`~repro.core.faults.TransactionMonitor`;
* :mod:`repro.net.remote` — ``RemoteNode``/``RemoteSharedObject``/
  ``RemoteObjectAccess`` duck-typing the in-process surface so
  ``Transaction``, ``TransactionMonitor``, and ``txstore`` run unchanged
  over either transport;
* :mod:`repro.net.spawn`  — subprocess helpers used by benchmarks, tests,
  and the distributed quickstart.

Trust model: frames carry pickles, so a node server must only be exposed to
trusted peers (localhost or a private cluster network) — exactly the
deployment model of Java RMI serialization in the source system.
"""
from .client import CLIENT_ID, NodeClient
from .remote import RemoteNode, RemoteObjectAccess, RemoteSharedObject
from .server import NodeServer
from .spawn import ServerHandle, spawn_server
from .wire import ConnectionClosed, WireError

__all__ = [
    "CLIENT_ID", "NodeClient", "RemoteNode", "RemoteObjectAccess",
    "RemoteSharedObject", "NodeServer", "ServerHandle", "spawn_server",
    "ConnectionClosed", "WireError",
]
