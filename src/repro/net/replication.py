"""Per-object replica chains: primary-side forwarding, follower-side state,
and the promotion state machine (DESIGN.md §8; ISSUE 6 tentpole part 2).

Each shared object may be bound with an ordered *follower* list. The
primary replicates in two phases keyed by the object's private version
(the §2.8.4 write log is applied at the primary first, and the *resulting
state* is what ships — direct ``txn_call`` modifications are covered too):

* **tentative** (``repl_apply``): sent at commit step 3 (``commit_prep``),
  under the object's header lock, *before* the wave reply that feeds the
  commit decision — so by the time any decision exists, every tentative is
  already in flight on a FIFO link that survives the primary's death;
* **final** (``repl_final``): sent at step 5 (terminate) — the follower
  applies the buffered tentative exactly once (``(epoch, seq)`` guard);
* **drop** (``repl_drop``): sent on abort/expiry — the tentative is
  discarded.

Commute-group members (§12) keep the same shape with one twist: their
fold is deferred past the commit decision, so the step-3 tentative ships
the *delta* (the buffered entry list, ``DELTA_MAGIC``-prefixed) instead
of a resulting-state snapshot, and the follower folds it into its
committed snapshot at resolution time. The tentative-before-decision
invariant therefore covers commute commits too.

The chained commit decision (tentpole part 1) additionally records a
per-transaction commit/abort *decision ledger* at followers
(``repl_decision`` / first-writer-wins doom), which is what makes a
primary crash between decision and terminate recoverable: a promoted
follower resolves dangling tentatives against the ledger, querying the
coordinator's decision memo (``txn_status``) for undecided ones and
dooming them to abort only when no coordinator survives to decide
otherwise.

Promotion is caller-driven and deterministic: every client (and the
decision chain's redirect) tries a dead primary's followers in the same
configured order, so they converge on the same new primary. A promoted
follower binds the replica payload into its registry under a FRESH version
header (old private versions are meaningless there; in-flight transactions
against the dead primary abort and retry) and continues replicating to the
followers after itself in the original order, at ``epoch + 1`` so its new
version sequence cannot be confused with the dead primary's.
"""
from __future__ import annotations

import logging
import pickle
import threading
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

from repro.net.wal import encode_delta, fold_payload
from repro.obs import txtrace as _txtrace

log = logging.getLogger("repro.net.replication")

#: Follower-side decision-ledger backstop (§10 GC): entries are normally
#: retired by the head's ``repl_retire`` once every chain member acked the
#: decision; the cap only bites when the head died before retiring, and
#: evicts oldest-first among entries no live tentative still references.
LEDGER_CAP = 512

#: Retired-commit memo (§10 GC): retirement must not make a committed
#: transaction indistinguishable from a never-decided one — a client whose
#: coordinator crashed after driving the full chain (acks in, entry
#: retired) but before its reply was delivered still recovers via
#: ``txn_decision``, and dooming that txn to abort would contradict the
#: already-applied commit. Only commits ever retire (aborts are never
#: broadcast), so a fixed-size ring of retired txn ids suffices: recovery
#: happens within a failover grace of the crash, far inside the ring's
#: horizon. Ids only — no chains, no payloads — so the ledger stays bounded.
RETIRED_MEMO_CAP = 512


class ReplicaRecord:
    """Follower-side state of one replicated object."""

    __slots__ = ("name", "primary", "order", "epoch", "payload",
                 "applied", "tentative", "promoted", "recovering")

    def __init__(self, name: str, primary: str, order: List[str],
                 epoch: int, payload: bytes, applied: Tuple[int, int]):
        self.name = name
        self.primary = primary
        self.order = list(order)         # follower addresses, primary first
        self.epoch = epoch
        self.payload = payload           # pickled last-applied state
        self.applied = applied           # (epoch, seq) of `payload`
        #: buffered tentatives: txn uid -> (epoch, seq, payload, head addr)
        self.tentative: Dict[str, Tuple[int, int, bytes, str]] = {}
        self.promoted = False
        #: True for a record rebuilt from a WAL replay (§11): the image
        #: may be missing commits that landed while this node was dead
        #: and departed from the quorum, so it must NOT be promotable
        #: until the anti-entropy rejoin replaces it with a live snapshot.
        self.recovering = False


class ReplicationManager:
    """Both halves of the replica-chain protocol for one node.

    Primary half: follower configuration, tentative/final/drop forwarding
    (one-ways, counted in ``n_sent`` for the bench's
    ``replication_oneways_per_txn``), and the coordinator's decision memo.
    Follower half: replica records, the decision ledger, and promotion.

    All state is guarded by one reentrant lock; sends happen outside it
    (a one-way to a slow peer must not stall the op path).
    """

    def __init__(self, core: Any):
        self.core = core                 # NodeCore (``_peer``, ``address``)
        self.lock = threading.RLock()
        # -- primary side ----------------------------------------------------
        self.followers: Dict[str, List[str]] = {}
        self.epochs: Dict[str, int] = {}
        self.pending: Dict[Tuple[str, str], Tuple[int, int]] = {}
        self.n_sent = 0                  # replication one-ways sent
        # -- decision ledger (coordinator memo + follower recoverability) ----
        self.decisions: Dict[str, str] = {}          # txn -> commit | abort
        self.chains: Dict[str, List[dict]] = {}      # txn -> decision chain
        # -- ledger GC (§10): head-side ack tracking + retirement -------------
        self._acks: Dict[str, set] = {}            # txn -> followers unacked
        self._retire_targets: Dict[str, List[str]] = {}
        self._ended: set = set()                    # txns safe to retire
        self.n_retired = 0
        #: retired *commit* ids (head and follower side): keeps a retired
        #: commit answerable — never doomed to abort — during the client
        #: recovery window. Bounded ring, oldest evicted first.
        self._retired_commits: "OrderedDict[str, None]" = OrderedDict()
        # -- follower side ---------------------------------------------------
        self.replicas: Dict[str, ReplicaRecord] = {}

    # ------------------------------------------------------------------ #
    # plumbing                                                           #
    # ------------------------------------------------------------------ #
    def _notify(self, address: str, op: str, *, count: bool = True,
                **kw: Any) -> None:
        try:
            self.core._peer(address).notify(op, **kw)
            if count:
                with self.lock:
                    self.n_sent += 1
        except Exception as e:  # noqa: BLE001 - dead follower: chain degrades
            log.debug("replication one-way %s -> %s failed: %r",
                      op, address, e)

    @property
    def _wal(self):
        """The hosting node's write-ahead ledger (§11), or None. Every
        durable fact is appended *before* the network send that announces
        it (WAL-before-network), so a crash between the two replays the
        fact instead of losing it."""
        return getattr(self.core, "wal", None)

    def _wal_decision(self, txn: str, decision: str, first: bool) -> None:
        if first and self._wal is not None:
            self._wal.decision(txn, decision)

    # ------------------------------------------------------------------ #
    # primary side                                                       #
    # ------------------------------------------------------------------ #
    def set_followers(self, name: str, followers: List[str],
                      obj: Any) -> None:
        """Configure the follower chain at bind time and seed each replica
        with the initial state (epoch 0, seq 0)."""
        followers = [f for f in followers if f != self.core.address]
        with self.lock:
            self.followers[name] = followers
            self.epochs.setdefault(name, 0)
        if not followers and self._wal is None:
            return
        payload = pickle.dumps(obj)
        if self._wal is not None:
            self._wal.bind(name, payload, followers, self.epochs[name])
        for f in followers:
            self._notify(f, "repl_init", count=False, name=name,
                         primary=self.core.address, order=list(followers),
                         epoch=self.epochs[name], payload=payload, seq=0)

    def followers_of(self, name: str) -> List[str]:
        with self.lock:
            return list(self.followers.get(name, ()))

    def on_commit_prep(self, txn: str, name: str, obj: Any, seq: int,
                       origin: Optional[str]) -> None:
        """Tentative replication at commit step 3: snapshot the applied
        state (caller holds the header lock — the snapshot must precede the
        release that wakes successors) and forward it to every follower."""
        fl = self.followers_of(name)
        if not fl and self._wal is None:
            return
        with self.lock:
            epoch = self.epochs.get(name, 0)
            self.pending[(txn, name)] = (epoch, seq)
        payload = pickle.dumps(obj)
        head = origin or self.core.address
        if self._wal is not None:
            self._wal.tentative(txn, name, epoch, seq, payload, head)
        for f in fl:
            self._notify(f, "repl_apply", name=name, txn=txn, epoch=epoch,
                         seq=seq, payload=payload, head=head)

    def on_commute_prep(self, txn: str, name: str, entries: List[tuple],
                        seq: int, origin: Optional[str]) -> None:
        """Tentative replication for a commute-group member (§12). The
        fold is deferred past the commit decision (it runs at terminate,
        under the merge lock), so what ships at step 3 is the *delta* —
        the member's buffered entry list, marked by :data:`DELTA_MAGIC`.
        Followers fold it into their committed snapshot on final/decision
        instead of overwriting (:meth:`_apply`), which keeps the §8
        invariant — every tentative is in flight before any decision
        exists — true for commute commits too: a primary crashing between
        decision and fold no longer takes the only copy of the deltas
        with it while the promoted follower acks the decide."""
        fl = self.followers_of(name)
        if not fl and self._wal is None:
            return
        with self.lock:
            epoch = self.epochs.get(name, 0)
            self.pending[(txn, name)] = (epoch, seq)
        payload = encode_delta(entries)
        head = origin or self.core.address
        if self._wal is not None:
            self._wal.tentative(txn, name, epoch, seq, payload, head)
        for f in fl:
            self._notify(f, "repl_apply", name=name, txn=txn, epoch=epoch,
                         seq=seq, payload=payload, head=head)

    def on_terminate(self, txn: str, name: str) -> None:
        """Final replication at step 5: promote the pending tentative."""
        with self.lock:
            key = self.pending.pop((txn, name), None)
        if key is None:
            return
        epoch, seq = key
        if self._wal is not None:
            # Durability point: the committed write is WAL'd (and the
            # batch fsynced) before the finals go out / the op returns.
            self._wal.final(txn, name, epoch, seq)
        for f in self.followers_of(name):
            self._notify(f, "repl_final", name=name, txn=txn, epoch=epoch,
                         seq=seq)

    def on_abort(self, txn: str, name: str) -> None:
        """Abort/expiry: the tentative (if any) must be discarded."""
        with self.lock:
            key = self.pending.pop((txn, name), None)
        if key is None:
            return
        if self._wal is not None:
            self._wal.drop(txn, name)
        for f in self.followers_of(name):
            self._notify(f, "repl_drop", name=name, txn=txn)

    # ------------------------------------------------------------------ #
    # decision ledger                                                    #
    # ------------------------------------------------------------------ #
    def record_decision(self, txn: str, decision: str,
                        chain: Optional[List[dict]] = None) -> str:
        """First-writer-wins decision ledger. Returns the winning decision
        (which may differ from ``decision`` if one was already recorded)."""
        with self.lock:
            first = txn not in self.decisions
            d = self.decisions.setdefault(txn, decision)
            self._wal_decision(txn, d, first)
            if chain is not None and d == decision:
                self.chains.setdefault(txn, list(chain))
            if d == "commit":
                self._resolve_tentatives_commit(txn)
            elif d == "abort":
                self._resolve_tentatives_abort(txn)
            self._trim_ledger()
        if _txtrace.enabled and first:
            # The commit/abort decision point (DESIGN.md §8) — the moment
            # the outcome became durable on this node's ledger.
            self.core.obs_tracer.instant(
                "commit_decide", txn=txn, detail=d,
                sev=_txtrace.INFO if d == "commit" else _txtrace.WARN)
        return d

    def decision_of(self, txn: str) -> Optional[str]:
        with self.lock:
            d = self.decisions.get(txn)
            if d is None and txn in self._retired_commits:
                return "commit"   # retired entries were all commits (§10 GC)
            return d

    def chain_of(self, txn: str) -> List[dict]:
        with self.lock:
            return list(self.chains.get(txn, ()))

    def broadcast_decision(self, txn: str, chain: List[dict]) -> None:
        """Make the commit decision recoverable before acting on it: ship
        it (with the remaining decision chain) to every follower of this
        node's own objects. If this node dies mid-drive, any one of them
        can re-drive the chain when a recovering client asks."""
        targets: set = set()
        with self.lock:
            for fl in self.followers.values():
                targets.update(fl)
            # GC bookkeeping: this node is the ledger *head* for ``txn``.
            # The entry retires (here and at every target) once every
            # target acked the decision AND the transaction ended locally.
            self._acks[txn] = set(targets)
            self._retire_targets[txn] = sorted(targets)
        for t in sorted(targets):
            self._notify(t, "repl_decision", txn=txn, decision="commit",
                         chain=chain, head=self.core.address)

    # ------------------------------------------------------------------ #
    # follower side                                                      #
    # ------------------------------------------------------------------ #
    def _apply(self, rec: ReplicaRecord, epoch: int, seq: int,
               payload: bytes) -> None:
        # ``>=``, not ``>``: every member of one commute group (§12) ships
        # its delta tentative at the group's shared seq ``cg_pv`` — an
        # equal-seq resolution must still fold, or the follower would keep
        # only the FIRST member's effect. fold_payload folds a delta into
        # the committed snapshot and lets a snapshot replace it; each
        # tentative resolves at most once (pop semantics everywhere), so
        # equal-seq folds never double-apply. Exact commits are unaffected
        # (their seqs are distinct, their payloads full snapshots). The
        # quiescence rule makes the guard safe for deltas too: a group only
        # forms when every earlier commit's final has been sent on this
        # same FIFO link, so a delta can never fold over a snapshot that is
        # missing a predecessor.
        if (epoch, seq) >= rec.applied:
            rec.payload = fold_payload(rec.payload, payload)
            rec.applied = (epoch, seq)

    def _resolve_tentatives_commit(self, txn: str) -> None:
        for rec in self.replicas.values():
            t = rec.tentative.pop(txn, None)
            if t is not None and not rec.promoted:
                self._apply(rec, t[0], t[1], t[2])

    def _resolve_tentatives_abort(self, txn: str) -> None:
        for rec in self.replicas.values():
            rec.tentative.pop(txn, None)

    def repl_init(self, name: str, primary: str, order: List[str],
                  epoch: int, payload: bytes, seq: int) -> None:
        with self.lock:
            rec = self.replicas.get(name)
            if rec is not None and (rec.promoted
                                    or rec.applied > (epoch, seq)):
                return   # stale (re)init from an older generation
            self.replicas[name] = ReplicaRecord(
                name, primary, order, epoch, payload, (epoch, seq))
        if self._wal is not None:
            self._wal.init(name, primary, list(order), epoch, seq, payload)
        leases = getattr(self.core, "leases", None)
        if leases is not None:
            # Implicit promise (§10): accepting a chain seat IS a promise
            # not to promote past this primary until its lease could have
            # lapsed. Without it, a takeover in the window before the
            # first renewal round would race a healthy, un-fenced primary
            # (promises otherwise only appear on ``lease_renew``).
            leases.on_grant(name, epoch, primary)

    def repl_apply(self, name: str, txn: str, epoch: int, seq: int,
                   payload: bytes, head: str) -> None:
        with self.lock:
            rec = self.replicas.get(name)
            if rec is None or rec.promoted or epoch < rec.epoch:
                return   # stale primary generation
            d = self.decisions.get(txn)
            if d == "abort":
                return   # drop on the floor
            if self._wal is not None:
                self._wal.tentative(txn, name, epoch, seq, payload, head)
            if d == "commit":
                self._apply(rec, epoch, seq, payload)
            else:
                rec.tentative[txn] = (epoch, seq, payload, head)

    def repl_final(self, name: str, txn: str, epoch: int, seq: int) -> None:
        with self.lock:
            rec = self.replicas.get(name)
            if rec is not None and epoch < rec.epoch:
                return   # fenced-out primary generation (§10): reject
            self.decisions.setdefault(txn, "commit")
            self._trim_ledger()
            if self._wal is not None:
                # the final record doubles as the commit decision at
                # replay (recover() folds it into the decision ledger)
                self._wal.final(txn, name, epoch, seq)
            if rec is None or rec.promoted:
                return
            t = rec.tentative.pop(txn, None)
            if t is not None:
                self._apply(rec, t[0], t[1], t[2])

    def repl_drop(self, name: str, txn: str) -> None:
        with self.lock:
            rec = self.replicas.get(name)
            if rec is not None:
                rec.tentative.pop(txn, None)

    def repl_decision(self, txn: str, decision: str,
                      chain: List[dict], head: Optional[str] = None) -> None:
        self.record_decision(txn, decision, chain)
        if head and head != self.core.address:
            # Ack the ledger head so it can retire the entry (§10 GC).
            self._notify(head, "repl_decision_ack", count=False,
                         txn=txn, node=self.core.address)

    # ------------------------------------------------------------------ #
    # ledger GC (§10)                                                    #
    # ------------------------------------------------------------------ #
    def repl_decision_ack(self, txn: str, node: str) -> None:
        with self.lock:
            pending = self._acks.get(txn)
            if pending is not None:
                pending.discard(node)
        self._maybe_retire(txn)

    def mark_ended(self, txn: str) -> None:
        """The transaction's commit drive completed on this node — its
        ledger entry may retire as soon as every follower has acked."""
        with self.lock:
            if txn not in self.decisions:
                return
            self._ended.add(txn)
        self._maybe_retire(txn)

    def _memo_retired(self, txn: str) -> None:
        """Remember a retired commit id (lock held by caller)."""
        self._retired_commits[txn] = None
        self._retired_commits.move_to_end(txn)
        while len(self._retired_commits) > RETIRED_MEMO_CAP:
            self._retired_commits.popitem(last=False)

    def _maybe_retire(self, txn: str) -> None:
        with self.lock:
            pending = self._acks.get(txn)
            if pending is None or pending or txn not in self._ended:
                return
            self._acks.pop(txn, None)
            self._ended.discard(txn)
            targets = self._retire_targets.pop(txn, [])
            self.decisions.pop(txn, None)
            self.chains.pop(txn, None)
            self._memo_retired(txn)
            self.n_retired += 1
        for t in targets:   # sends outside the lock, like every one-way
            self._notify(t, "repl_retire", count=False, txn=txn)

    def repl_retire(self, txn: str) -> None:
        """Head says every chain member acked: drop the ledger entry. Any
        tentative for ``txn`` was resolved before this node's ack went out
        (FIFO link: repl_apply ≺ repl_decision ≺ our ack ≺ repl_retire)."""
        with self.lock:
            self.decisions.pop(txn, None)
            self.chains.pop(txn, None)
            self._memo_retired(txn)

    def fully_acked_unretired(self) -> int:
        """Invariant probe: at convergence this is 0 — every fully-acked,
        ended entry has been retired (simsweep ledger-boundedness check)."""
        with self.lock:
            return sum(1 for txn, pending in self._acks.items()
                       if not pending and txn in self._ended)

    def ledger_stats(self) -> Dict[str, int]:
        with self.lock:
            return {"decisions": len(self.decisions),
                    "heads_unretired": len(self._acks),
                    "fully_acked_unretired": self.fully_acked_unretired(),
                    "retired": self.n_retired,
                    "retired_memo": len(self._retired_commits)}

    def _trim_ledger(self) -> None:
        """Follower-side backstop: bound the ledger even if heads died
        before retiring. Must be called with the lock held."""
        if len(self.decisions) <= LEDGER_CAP:
            return
        referenced = {txn for rec in self.replicas.values()
                      for txn in rec.tentative}
        for txn in list(self.decisions):
            if len(self.decisions) <= LEDGER_CAP:
                break
            if txn in self._acks or txn in referenced:
                continue   # head-tracked / still resolving: never evict
            self.decisions.pop(txn, None)
            self.chains.pop(txn, None)

    # ------------------------------------------------------------------ #
    # promotion                                                          #
    # ------------------------------------------------------------------ #
    def head_of(self, txn: str) -> Optional[str]:
        """Coordinator address recorded on any buffered tentative of
        ``txn``, or ``None`` if no replica here holds one."""
        with self.lock:
            for rec in self.replicas.values():
                t = rec.tentative.get(txn)
                if t is not None:
                    return t[3]
        return None

    def _query_head(self, head: str, txn: str) -> str:
        """Ask a tentative's coordinator for the transaction's fate.
        An unreachable coordinator answers ``unreachable`` — under §11 it
        may be mid-restart holding a durable ``commit``, so only callers
        protected by epoch fencing (promotion: a returning rival's
        contradicting fold is discarded when it defers to the successor
        chain) may doom on it immediately; resurrection must poll it out
        first (no rival chain exists to fence the disagreement away)."""
        try:
            return self.core._peer(head).call("txn_status", txn=txn)
        except Exception:  # noqa: BLE001 - dead (or restarting) coordinator
            return "unreachable"

    def promote(self, names: List[str]) -> Dict[str, List[str]]:
        """Attempt to take over as primary for ``names``.

        Returns ``{"promoted": [...], "busy": [...]}``; names in neither
        list are unknown here (the caller tries the next follower). A name
        is *busy* while some tentative's coordinator is alive but
        undecided — the caller retries: a live coordinator's chained
        commit is synchronous, so the window is bounded.
        """
        promoted: List[str] = []
        busy: List[str] = []
        for name in names:
            lm = getattr(self.core, "leases", None)
            moved = lm is not None and name in lm.moved
            if self.core.has_binding(name) and not moved:
                promoted.append(name)    # already primary here: idempotent
                continue
            with self.lock:
                rec = self.replicas.get(name)
                if rec is None:
                    continue
                if rec.promoted:
                    promoted.append(name)
                    continue
                if rec.recovering:
                    # A replayed image may be missing commits that landed
                    # while we were dead (§11): promoting it would serve
                    # stale state — refuse retryably until the rejoin
                    # catch-up replaces the record.
                    busy.append(name)
                    continue
                pending_txns = [
                    (txn, t) for txn, t in rec.tentative.items()
                    if txn not in self.decisions]
            wait = False
            for txn, t in pending_txns:
                status = self._query_head(t[3], txn)
                if status == "pending":
                    wait = True
                    break
                with self.lock:
                    # first-writer-wins: a racing repl_decision beats us
                    first = txn not in self.decisions
                    d = self.decisions.setdefault(
                        txn, "commit" if status == "commit" else "abort")
                    self._wal_decision(txn, d, first)
            if wait:
                busy.append(name)
                continue
            with self.lock:
                for txn in list(rec.tentative):
                    d = self.decisions.get(txn)
                    t = rec.tentative.pop(txn)
                    if d == "commit":
                        self._apply(rec, t[0], t[1], t[2])
                self._activate(name, rec)
            promoted.append(name)
        return {"promoted": promoted, "busy": busy}

    def _activate(self, name: str, rec: ReplicaRecord) -> None:
        """Become primary: bind the replica state into the local registry
        under a fresh header and continue the chain at ``epoch + 1``."""
        obj = pickle.loads(rec.payload)
        self.core.bind_local(name, obj)
        me = self.core.address
        tail = rec.order[rec.order.index(me) + 1:] if me in rec.order else []
        epoch = rec.applied[0] + 1
        self.followers[name] = tail
        self.epochs[name] = epoch
        rec.promoted = True
        if self._wal is not None:
            self._wal.bind(name, rec.payload, tail, epoch)
        leases = getattr(self.core, "leases", None)
        if leases is not None:
            # Ownership is lease-based (§10): the promotion IS a lease
            # grant at the new epoch — renewal over `tail` starts now.
            leases.grant_local(name, epoch)
        log.info("promoted to primary of %r (epoch %d, %d followers)",
                 name, epoch, len(tail))
        if tail:
            for f in tail:
                self._notify(f, "repl_init", count=False, name=name,
                             primary=me, order=tail, epoch=epoch,
                             payload=rec.payload, seq=0)

    # ------------------------------------------------------------------ #
    # ownership migration (§10)                                          #
    # ------------------------------------------------------------------ #
    def adopt(self, name: str, followers: List[str], epoch: int,
              payload: bytes) -> None:
        """Become primary of ``name`` by *handoff* (migrate_in): take over
        the chain at the shipped epoch, re-seed the followers, and mark any
        local replica record promoted so the old primary's stale one-ways
        are ignored."""
        followers = [f for f in followers if f != self.core.address]
        with self.lock:
            self.followers[name] = list(followers)
            self.epochs[name] = epoch
            rec = self.replicas.get(name)
            if rec is not None:
                rec.promoted = True
        if self._wal is not None:
            self._wal.bind(name, payload, list(followers), epoch)
        for f in followers:
            self._notify(f, "repl_init", count=False, name=name,
                         primary=self.core.address, order=list(followers),
                         epoch=epoch, payload=payload, seq=0)

    def drop_primary(self, name: str) -> None:
        """Old primary after a successful handoff: stop replicating."""
        with self.lock:
            self.followers.pop(name, None)

    # ------------------------------------------------------------------ #
    # restart + chain rejoin (§11)                                       #
    # ------------------------------------------------------------------ #
    def rejoin_accept(self, name: str, addr: str,
                      payload: bytes) -> Dict[str, Any]:
        """Primary side of a restarted node's chain rejoin: grow the
        chain back by appending ``addr`` as the tail follower and hand it
        the quiesced committed snapshot (anti-entropy catch-up, snapshot
        form — the chain's native replication unit is the full state, so
        one snapshot IS the delta). The caller (``_op_repl_rejoin``) has
        already drained the object, so ``payload`` is the whole truth:
        no in-flight versions, no pending tentatives. The surviving
        followers learn the grown order via ``repl_chain`` one-ways."""
        me = self.core.address
        with self.lock:
            fl = list(self.followers.get(name, ()))
            if addr != me and addr not in fl:
                fl.append(addr)
            self.followers[name] = fl
            epoch = self.epochs.get(name, 0)
        if self._wal is not None:
            self._wal.membership(name, list(fl), list(fl))
        for f in fl:
            if f != addr:
                self._notify(f, "repl_chain", count=False, name=name,
                             order=list(fl), epoch=epoch)
        return {"name": name, "primary": me, "order": list(fl),
                "epoch": epoch, "seq": 0, "payload": payload}

    def repl_chain(self, name: str, order: List[str], epoch: int) -> None:
        """Chain-membership update (a restarted node rejoined as tail):
        adopt the grown order so a future promotion replicates to — and a
        future rejoin probes — the full healed chain."""
        with self.lock:
            rec = self.replicas.get(name)
            if rec is None or rec.promoted or epoch < rec.epoch:
                return
            rec.order = list(order)
        if self._wal is not None:
            self._wal.membership(name, list(order), [])

    # ------------------------------------------------------------------ #
    # client recovery                                                    #
    # ------------------------------------------------------------------ #
    def txn_decision(self, txn: str) -> Tuple[str, List[dict]]:
        """A recovering client asks a follower of the dead coordinator for
        the transaction's fate. No recorded decision means the coordinator
        died before making it recoverable — doom to abort, first-writer-
        wins (atomic either way: the decision broadcast precedes every
        effect of the decision, so a doomed transaction committed
        nowhere). A *retired* commit (fully acked + GC'd before the
        client's reply arrived — e.g. the coordinator crashed between the
        decision drive and the reply send) answers ``commit`` from the
        retired memo: its chain already drove to completion everywhere, so
        no re-drive is needed."""
        with self.lock:
            if txn not in self.decisions and txn in self._retired_commits:
                return "commit", []
            first = txn not in self.decisions
            d = self.decisions.setdefault(txn, "abort")
            self._wal_decision(txn, d, first)
            if d == "abort":
                self._resolve_tentatives_abort(txn)
                return d, []
            self._resolve_tentatives_commit(txn)
            return d, list(self.chains.get(txn, ()))
