"""RPC client: connection pool + liveness heartbeat (DESIGN.md §3.1).

One :class:`NodeClient` per (client process, node server). RPCs are strict
request/response over pooled TCP connections — a blocking RPC (gate wait,
task join) holds its pooled connection for the duration, and concurrency
comes from the pool growing on demand up to ``max_pool``.

Failure mapping (§3.4): any socket-level failure flips the client to
``alive = False`` (crash-stop — a node that vanished is *removed from the
system*) and surfaces as :class:`~repro.core.api.RemoteObjectFailure`, which
the transaction machinery already routes through its abort path.

Liveness has two halves:

* **heartbeat** — while this process has live transactions on the server, a
  daemon thread sends a periodic ``heartbeat`` RPC naming them; the server
  refreshes the §3.4 failure detector for every object they hold.
* **presence connection** — one dedicated idle connection announced with
  ``hello``. The server maps it to this client's sessions; the OS closing
  it (process death) immediately expires every held object, so the
  server-side :class:`~repro.core.faults.TransactionMonitor` rolls them
  back without waiting a full detector timeout.
"""
from __future__ import annotations

import os
import socket
import threading
import uuid
from collections import deque
from typing import Any, Deque, Optional, Set

from repro.core.api import RemoteObjectFailure

from .wire import (ConnectionClosed, ERR, OK, WireError, parse_address,
                   recv_msg, send_msg)

#: Stable identity of this client *process* across all its transactions.
CLIENT_ID = f"{socket.gethostname()}:{os.getpid()}:{uuid.uuid4().hex[:6]}"


class NodeClient:
    """Connection-pooled RPC endpoint for one node server."""

    def __init__(self, address: str, *, connect_timeout: float = 5.0,
                 heartbeat_interval: float = 0.5, max_pool: int = 64):
        self.address = address
        self.host, self.port = parse_address(address)
        self.connect_timeout = connect_timeout
        self.heartbeat_interval = heartbeat_interval
        self.max_pool = max_pool
        self.alive = True
        self._pool: Deque[socket.socket] = deque()
        self._pool_size = 0
        self._lock = threading.Lock()
        self._pool_slot = threading.Condition(self._lock)
        self._active_txns: Set[str] = set()
        self._presence: Optional[socket.socket] = None
        self._presence_lock = threading.Lock()   # single presence conn ever
        self._hb_thread: Optional[threading.Thread] = None
        self._closed = threading.Event()

    # -- connections --------------------------------------------------------
    def _connect(self, *, mark_on_fail: bool = True) -> socket.socket:
        try:
            sock = socket.create_connection((self.host, self.port),
                                            timeout=self.connect_timeout)
        except OSError as e:
            if mark_on_fail:
                self._mark_dead()
            raise RemoteObjectFailure(
                f"node server {self.address} is unreachable: {e}") from e
        sock.settimeout(None)  # blocking RPCs may legitimately take long
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return sock

    def _checkout(self) -> socket.socket:
        with self._lock:
            if not self.alive:
                raise RemoteObjectFailure(
                    f"node server {self.address} is unreachable (crash-stop)")
            if self._pool:
                return self._pool.popleft()
            while self._pool_size >= self.max_pool:
                self._pool_slot.wait(timeout=30.0)
                if not self.alive:   # died while we waited for a slot
                    raise RemoteObjectFailure(
                        f"node server {self.address} is unreachable "
                        f"(crash-stop)")
                if self._pool:
                    return self._pool.popleft()
            self._pool_size += 1
        try:
            return self._connect()
        except BaseException:
            with self._lock:
                self._pool_size -= 1
                self._pool_slot.notify()
            raise

    def _checkin(self, sock: Optional[socket.socket]) -> None:
        with self._lock:
            if sock is not None and self.alive and not self._closed.is_set():
                self._pool.append(sock)
            else:
                self._pool_size -= 1
                if sock is not None:
                    try:
                        sock.close()
                    except OSError:
                        pass
            self._pool_slot.notify()

    def _mark_dead(self) -> None:
        with self._lock:
            self.alive = False
            stale = list(self._pool)
            self._pool.clear()
            self._pool_size -= len(stale)   # their slots are gone for good
            self._pool_slot.notify_all()    # wake waiters to observe death
        for s in stale:
            try:
                s.close()
            except OSError:
                pass

    # -- RPC ----------------------------------------------------------------
    def call(self, op: str, **kwargs: Any) -> Any:
        """Invoke ``op`` on the server; returns its value or re-raises its
        error. Socket failures map to :class:`RemoteObjectFailure`."""
        sock = self._checkout()
        try:
            send_msg(sock, (op, kwargs))
            status, value = recv_msg(sock)
        except (ConnectionClosed, WireError, OSError) as e:
            # WireError (undecodable reply) is connection-fatal too: the
            # stream position is unknown, so the socket cannot be reused.
            try:
                sock.close()
            except OSError:
                pass
            self._checkin(None)
            self._mark_dead()
            raise RemoteObjectFailure(
                f"node server {self.address} failed mid-call ({op}): {e}") from e
        self._checkin(sock)
        if status == OK:
            return value
        assert status == ERR
        raise value

    # -- transaction liveness ----------------------------------------------
    def register_txn(self, txn_uid: str) -> None:
        """Track a live transaction: start heartbeating + presence."""
        with self._lock:
            self._active_txns.add(txn_uid)
            need_hb = self._hb_thread is None
        self._ensure_presence()   # no-op once established
        if need_hb:
            t = threading.Thread(target=self._heartbeat_loop,
                                 name=f"hb-{self.address}", daemon=True)
            with self._lock:
                if self._hb_thread is None:
                    self._hb_thread = t
                    t.start()

    def finish_txn(self, txn_uid: str) -> None:
        """The transaction terminated everywhere: drop the server session."""
        with self._lock:
            if txn_uid not in self._active_txns:
                return
            self._active_txns.discard(txn_uid)
        try:
            self.call("end_txn", txn=txn_uid)
        except RemoteObjectFailure:
            pass  # server is gone; nothing left to clean up there

    def _ensure_presence(self) -> None:
        # Serialized: a duplicate presence connection for the same client id
        # would later be dropped (overwritten + GC-closed) and the server
        # would mistake that for this whole process crashing.
        with self._presence_lock:
            with self._lock:
                if self._presence is not None or not self.alive:
                    return
            try:
                # Best-effort: a transient refusal (backlog overflow, port
                # exhaustion) must not crash-stop a healthy server for the
                # whole client, so this connect never marks the client dead.
                sock = self._connect(mark_on_fail=False)
                send_msg(sock, ("hello", {"client_id": CLIENT_ID}))
                status, _ = recv_msg(sock)
                if status != OK:
                    raise ConnectionClosed("hello rejected")
            except (RemoteObjectFailure, ConnectionClosed, OSError):
                return  # heartbeats still cover liveness (slower detection)
            with self._lock:
                self._presence = sock

    def _heartbeat_loop(self) -> None:
        # The heartbeat owns a dedicated connection: sharing the bounded
        # pool would let max_pool threads blocked in long gate waits starve
        # liveness, and the server would roll back live transactions.
        sock: Optional[socket.socket] = None
        try:
            while not self._closed.wait(self.heartbeat_interval):
                with self._lock:
                    txns = list(self._active_txns)
                    alive = self.alive
                if not alive:
                    return
                if not txns:
                    continue
                try:
                    if sock is None:
                        sock = self._connect()
                    send_msg(sock, ("heartbeat",
                                    {"client_id": CLIENT_ID, "txns": txns}))
                    status, value = recv_msg(sock)
                    if status == ERR and isinstance(value, BaseException):
                        continue   # server-side hiccup; beat again next tick
                except RemoteObjectFailure:
                    return         # _connect marked the server dead
                except Exception:  # noqa: BLE001 - transient: reconnect
                    if sock is not None:
                        try:
                            sock.close()
                        except OSError:
                            pass
                        sock = None
        finally:
            if sock is not None:
                try:
                    sock.close()
                except OSError:
                    pass

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        self._closed.set()
        with self._lock:
            pool = list(self._pool)
            self._pool.clear()
            presence, self._presence = self._presence, None
        for s in pool + ([presence] if presence else []):
            try:
                s.close()
            except OSError:
                pass

    def __repr__(self) -> str:  # pragma: no cover
        return f"NodeClient({self.address}, alive={self.alive})"
