"""RPC client: one multiplexed, pipelined connection per node (DESIGN.md §3.1).

One :class:`NodeClient` per (client process, node server), owning **one**
framed TCP connection. Every request is tagged with a request id; a
dedicated reader thread demultiplexes replies to per-call
:class:`Future`\\ s, so any number of caller threads share the socket and a
blocking RPC (gate wait, task join) costs an outstanding request id, not a
held connection. :meth:`NodeClient.call_async` issues without waiting —
the pipelining primitive the transaction hot path is built on.

**One-way messages** (:meth:`notify`) carry no request id and expect no
reply: §2.7 read-only-buffering kickoffs, §2.8.4 last-write apply kickoffs,
release/terminate notifications, heartbeats. Server-side failures of
one-way ops come back as ``oneway_err`` *notes* and are recorded per
transaction; :meth:`raise_deferred` surfaces them at the transaction's next
sync point (error deferral, per the paper's asynchrony model: an
asynchronous operation's error belongs to the operation that awaits it).

**Pushed task notes**: when a §2.7/§2.8.4 home-node task completes, the
server pushes a ``task_done`` note on this same connection (piggybacked on
an in-flight reply when one is departing, a standalone push otherwise),
carrying the task's outcome and — when small — the pickled state of the
read buffer it produced. ``join`` of a release task is then a local wait,
and buffered reads execute against the shipped state: usually zero extra
round trips.

Failure mapping (§3.4): any socket-level failure flips the client to
``alive = False`` (crash-stop — a node that vanished is *removed from the
system*), **fails every in-flight future and task wait** so no caller
hangs, and surfaces as :class:`~repro.core.api.RemoteObjectFailure`, which
the transaction machinery already routes through its abort path.

Liveness rides the same link: the connection announces itself with
``mux_hello`` (the server maps it to this process's sessions — the OS
closing it is the instant crash-stop signal that replaces PR 2's dedicated
presence connection), and while this process has live transactions a
daemon thread sends one-way ``heartbeat`` messages naming them.
"""
from __future__ import annotations

import itertools
import logging
import os
import pickle
import socket
import threading
import uuid
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.api import RemoteObjectFailure

from .wire import (ConnectionClosed, FrameReader, NOTE, OK, WireError,
                   parse_address, recv_msg, send_msg)

log = logging.getLogger("repro.net.client")

#: Stable identity of this client *process* across all its transactions.
CLIENT_ID = f"{socket.gethostname()}:{os.getpid()}:{uuid.uuid4().hex[:6]}"


class Future:
    """Completion handle for one in-flight request."""

    __slots__ = ("_done", "_value", "_error")

    def __init__(self):
        self._done = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None

    def set_result(self, value: Any) -> None:
        self._value = value
        self._done.set()

    def set_error(self, error: BaseException) -> None:
        self._error = error
        self._done.set()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._done.wait(timeout):
            raise TimeoutError("RPC reply did not arrive in time")
        if self._error is not None:
            raise self._error
        return self._value


class _LocalBuf:
    """Client-side copy of a home-node read buffer (piggyback protocol).

    Holds the unpickled ``__tx_snapshot__`` state a ``task_done`` note (or a
    ``buffer_snapshot`` reply) shipped because it was small; buffered reads
    then execute locally with zero round trips. Duck-types the ``call``
    surface of :class:`~repro.core.buffers.CopyBuffer`.
    """

    __slots__ = ("state",)

    def __init__(self, state: Any):
        self.state = state

    def call(self, method: str, args: tuple, kwargs: dict) -> Any:
        return getattr(self.state, method)(*args, **kwargs)

    def __repr__(self) -> str:  # pragma: no cover
        return f"_LocalBuf({type(self.state).__name__})"


def load_buf(payload: Optional[bytes]) -> Optional[_LocalBuf]:
    """Unpickle a piggybacked buffer state; ``None`` stays ``None``."""
    if payload is None:
        return None
    try:
        return _LocalBuf(pickle.loads(payload))
    except Exception:  # noqa: BLE001 - class not importable here: read remotely
        return None


class _TaskWait:
    """Local completion state of one fire-and-forget home-node task."""

    __slots__ = ("done", "error", "buf")

    def __init__(self):
        self.done = threading.Event()
        self.error: Optional[BaseException] = None
        self.buf: Optional[_LocalBuf] = None


class _Mux:
    """One established multiplexed connection (socket + write-side lock)."""

    __slots__ = ("sock", "send_lock")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.send_lock = threading.Lock()


class NodeClient:
    """Multiplexed RPC endpoint for one node server.

    A small fixed set of mux connections (``conns``) is shared by all
    caller threads with *per-thread affinity*: each thread is pinned to one
    connection, so every message sequence a single transaction produces is
    FIFO on its wire (one-way kickoffs are processed before the requests
    pipelined behind them), while independent client threads get
    independent reader/writer pipelines — one serial reader never becomes
    the throughput ceiling of the whole process.
    """

    def __init__(self, address: str, *, connect_timeout: float = 5.0,
                 heartbeat_interval: float = 0.5, conns: int = 4):
        self.address = address
        self.host, self.port = parse_address(address)
        self.connect_timeout = connect_timeout
        self.heartbeat_interval = heartbeat_interval
        self.alive = True
        self._muxes: List[Optional[_Mux]] = [None] * max(1, conns)
        self._tl = threading.local()            # per-thread conn affinity
        self._rr = itertools.count()            # round-robin assignment
        self._conn_lock = threading.Lock()      # connection establishment
        self._lock = threading.Lock()           # client state
        self._req_ids = itertools.count(1)
        self._pending: Dict[int, Future] = {}
        self._tasks: Dict[Tuple[str, str], _TaskWait] = {}
        self._deferred: Dict[str, List[BaseException]] = {}
        self._active_txns: Set[str] = set()
        self._ended: Set[str] = set()           # server already dropped these
        self._hb_thread: Optional[threading.Thread] = None
        self._closed = threading.Event()

    # -- connection ----------------------------------------------------------
    def _mux_for_thread(self) -> _Mux:
        idx = getattr(self._tl, "idx", None)
        if idx is None:
            idx = next(self._rr) % len(self._muxes)
            self._tl.idx = idx
        mux = self._muxes[idx]
        return mux if mux is not None else self._establish(idx)

    def _establish(self, idx: int) -> _Mux:
        with self._conn_lock:
            if self._muxes[idx] is not None:
                return self._muxes[idx]
            if not self.alive or self._closed.is_set():
                raise RemoteObjectFailure(
                    f"node server {self.address} is unreachable (crash-stop)")
            try:
                sock = socket.create_connection((self.host, self.port),
                                                timeout=self.connect_timeout)
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                # Handshake before the reader exists: announce this process
                # (the server maps the connection to our sessions — the drop
                # of our last connection is the §3.4 instant crash-stop
                # signal) and await the ack on the still-private socket.
                send_msg(sock, (0, "mux_hello", {"client_id": CLIENT_ID}))
                req_id, status, value, _notes = recv_msg(sock)
                if req_id != 0 or status != OK:
                    raise ConnectionClosed("mux_hello rejected")
                sock.settimeout(None)   # replies may legitimately take long
            except (OSError, ConnectionClosed, WireError) as e:
                # A transient refusal (backlog overflow, port exhaustion)
                # establishing a *supplementary* connection must not
                # crash-stop the whole client while an established healthy
                # connection exists: re-pin this thread onto one instead.
                for i, mux in enumerate(self._muxes):
                    if mux is not None and self.alive:
                        self._tl.idx = i
                        return mux
                self._mark_dead(f"connect failed: {e}")
                raise RemoteObjectFailure(
                    f"node server {self.address} is unreachable: {e}") from e
            mux = _Mux(sock)
            self._muxes[idx] = mux
            threading.Thread(
                target=self._reader_loop, args=(mux,),
                name=f"mux-reader-{self.address}-{idx}", daemon=True).start()
            return mux

    def _send(self, msg: Any) -> None:
        mux = self._mux_for_thread()
        try:
            with mux.send_lock:
                send_msg(mux.sock, msg)
        except (OSError, WireError) as e:
            self._mark_dead(f"send failed: {e}")
            raise RemoteObjectFailure(
                f"node server {self.address} failed mid-send: {e}") from e

    # -- reader thread (one per mux connection) ------------------------------
    def _reader_loop(self, mux: _Mux) -> None:
        reader = FrameReader(mux.sock)
        try:
            while True:
                req_id, status, value, notes = reader.recv_msg()
                for note in notes or ():
                    self._handle_note(note)
                if req_id is None or status == NOTE:
                    continue
                with self._lock:
                    fut = self._pending.pop(req_id, None)
                if fut is None:
                    # Late reply after a client-side timeout abandoned the
                    # call: drop it — the conversation moved on.
                    log.warning("dropping reply with unknown request id %r "
                                "from %s (late reply after timeout?)",
                                req_id, self.address)
                    continue
                if status == OK:
                    fut.set_result(value)
                else:
                    fut.set_error(value)
        except (ConnectionClosed, WireError, OSError) as e:
            if not self._closed.is_set():
                self._mark_dead(f"connection lost: {e}")

    def _handle_note(self, note: Dict[str, Any]) -> None:
        kind = note.get("kind")
        if kind == "task_done":
            key = (note["txn"], note["name"])
            with self._lock:
                if note["txn"] not in self._active_txns:
                    log.debug("dropping task note for finished txn %r", key)
                    return
                wait = self._tasks.setdefault(key, _TaskWait())
            wait.error = note.get("error")
            wait.buf = load_buf(note.get("buf"))
            wait.done.set()
        elif kind == "oneway_err":
            txn = note.get("txn")
            err = note.get("error") or RuntimeError("one-way op failed")
            log.debug("deferred one-way error for txn %r op %r: %r",
                      txn, note.get("op"), err)
            if txn is None:
                return
            with self._lock:
                active = txn in self._active_txns
                if active:
                    self._deferred.setdefault(txn, []).append(err)
            if not active:
                # Arrived after the transaction finished locally (e.g. a
                # pipelined step-5 terminate racing a §3.4 expiry): there
                # is no sync point left to raise it at — the epoch
                # machinery keeps the system consistent, but make the
                # partial termination visible.
                log.warning("one-way %r failed for finished txn %r: %r",
                            note.get("op"), txn, err)
                return
            # A failed kickoff never produces a completion note: fail the
            # task wait too, or its joiner would hang forever.
            if note.get("op") in ("ro_buffer", "lw_apply") and note.get("name"):
                wait = self._task_wait(txn, note["name"])
                wait.error = err
                wait.done.set()
        else:  # pragma: no cover - forward compatibility
            log.warning("ignoring unknown note kind %r from %s",
                        kind, self.address)

    # -- RPC -----------------------------------------------------------------
    def call_async(self, op: str, **kwargs: Any) -> Future:
        """Issue ``op`` without waiting; returns a :class:`Future`."""
        fut = Future()
        with self._lock:
            if not self.alive:
                raise RemoteObjectFailure(
                    f"node server {self.address} is unreachable (crash-stop)")
            req_id = next(self._req_ids)
            self._pending[req_id] = fut
        try:
            self._send((req_id, op, kwargs))
        except BaseException:
            with self._lock:
                self._pending.pop(req_id, None)
            raise
        return fut

    def call(self, op: str, rpc_timeout: Optional[float] = None,
             **kwargs: Any) -> Any:
        """Invoke ``op`` and wait for its reply (value or re-raised error).

        ``rpc_timeout`` bounds the *wait*, not the server-side execution: on
        expiry the future is abandoned (its late reply will be dropped by
        the reader) and :class:`TimeoutError` raised."""
        fut = self.call_async(op, **kwargs)
        try:
            return fut.result(rpc_timeout)
        except TimeoutError:
            with self._lock:
                stale = [rid for rid, f in self._pending.items() if f is fut]
                for rid in stale:
                    del self._pending[rid]
            raise

    def notify(self, op: str, **kwargs: Any) -> None:
        """Fire-and-forget one-way message: no reply, errors deferred
        (server reports them as ``oneway_err`` notes; see
        :meth:`raise_deferred`)."""
        self._send((None, op, kwargs))

    # -- deferred errors and task notes --------------------------------------
    def raise_deferred(self, txn_uid: str) -> None:
        """Sync point: raise the first deferred one-way error of ``txn_uid``
        recorded since the last sync point, if any."""
        with self._lock:
            errors = self._deferred.pop(txn_uid, None)
        if errors:
            raise errors[0]

    def _task_wait(self, txn_uid: str, name: str) -> _TaskWait:
        with self._lock:
            return self._tasks.setdefault((txn_uid, name), _TaskWait())

    def task_wait(self, txn_uid: str, name: str) -> _TaskWait:
        """The local completion handle of a fire-and-forget home-node task
        (created on kickoff, resolved by the pushed ``task_done`` note, a
        carrier reply via :meth:`resolve_task`, or :meth:`_mark_dead`)."""
        return self._task_wait(txn_uid, name)

    def resolve_task(self, txn_uid: str, name: str,
                     error: Optional[BaseException],
                     buf: Optional[bytes]) -> None:
        """Resolve a task wait from a result that rode back on a carrier
        reply (e.g. an inline-completed §2.7 task on the dispense reply)."""
        wait = self._task_wait(txn_uid, name)
        wait.error = error
        wait.buf = load_buf(buf)
        wait.done.set()

    # -- failure (§3.4 crash-stop) -------------------------------------------
    def _mark_dead(self, reason: str) -> None:
        with self._lock:
            already = not self.alive
            self.alive = False
            muxes = [m for m in self._muxes if m is not None]
            self._muxes = [None] * len(self._muxes)
            pending = list(self._pending.values())
            self._pending.clear()
            waits = list(self._tasks.values())
        if already and not muxes and not pending and not waits:
            return
        err = RemoteObjectFailure(
            f"node server {self.address} is unreachable ({reason})")
        # No waiter hangs: every in-flight future and task join observes
        # the death immediately.
        for fut in pending:
            fut.set_error(err)
        for w in waits:
            if not w.done.is_set():
                w.error = err
                w.done.set()
        for mux in muxes:
            try:
                mux.sock.close()
            except OSError:
                pass

    # -- transaction liveness ------------------------------------------------
    def register_txn(self, txn_uid: str) -> None:
        """Track a live transaction: liveness (hello + heartbeat) rides the
        mux connection."""
        with self._lock:
            self._active_txns.add(txn_uid)
            need_hb = self._hb_thread is None
        self._mux_for_thread()
        if need_hb:
            t = threading.Thread(target=self._heartbeat_loop,
                                 name=f"hb-{self.address}", daemon=True)
            with self._lock:
                if self._hb_thread is None:
                    self._hb_thread = t
                    t.start()

    def mark_session_ended(self, txn_uid: str) -> None:
        """The server already dropped this session (``finish_batch`` with
        ``end``): :meth:`finish_txn` skips its trailing ``end_txn``."""
        with self._lock:
            self._ended.add(txn_uid)

    def finish_txn(self, txn_uid: str) -> None:
        """The transaction terminated everywhere: drop the server session
        and every local trace of the transaction."""
        with self._lock:
            if txn_uid not in self._active_txns:
                return
            self._active_txns.discard(txn_uid)
            self._deferred.pop(txn_uid, None)
            ended = txn_uid in self._ended
            self._ended.discard(txn_uid)
            for key in [k for k in self._tasks if k[0] == txn_uid]:
                del self._tasks[key]
        if ended:
            return
        try:
            self.notify("end_txn", txn=txn_uid)
        except RemoteObjectFailure:
            pass  # server is gone; nothing left to clean up there

    def _heartbeat_loop(self) -> None:
        while not self._closed.wait(self.heartbeat_interval):
            with self._lock:
                txns = list(self._active_txns)
                alive = self.alive
            if not alive:
                return
            if not txns:
                continue
            try:
                self.notify("heartbeat", client_id=CLIENT_ID, txns=txns)
            except RemoteObjectFailure:
                return             # the mux died; crash-stop already handled

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        self._closed.set()
        with self._lock:
            muxes = [m for m in self._muxes if m is not None]
            self._muxes = [None] * len(self._muxes)
            pending = list(self._pending.values())
            self._pending.clear()
            waits = list(self._tasks.values())
        err = RemoteObjectFailure(f"client for {self.address} closed")
        for fut in pending:
            fut.set_error(err)
        for w in waits:
            if not w.done.is_set():
                w.error = err
                w.done.set()
        for mux in muxes:
            try:
                mux.sock.close()
            except OSError:
                pass

    def __repr__(self) -> str:  # pragma: no cover
        return f"NodeClient({self.address}, alive={self.alive})"
