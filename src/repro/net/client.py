"""RPC client: multiplexed pipelined connections with **leader/follower
demultiplexing** (DESIGN.md §3.1 v3).

One :class:`NodeClient` per (client process, node server), owning a small
fixed set of framed TCP connections with per-thread affinity. Every request
is tagged with a request id; :meth:`NodeClient.call_async` issues without
waiting — the pipelining primitive the transaction hot path is built on.

**Leader/follower.** A caller blocked on a :class:`Future` does not park
behind a reader thread: it takes over its connection's read loop (becomes
the *leader*), demultiplexes incoming frames inline — resolving other
callers' futures and handling pushes as they appear — and returns the
moment its own reply arrives, promoting a waiting *follower* to leader on
the way out. The common RPC therefore completes with **zero thread
handoffs**: the reply is read by the very thread that wants it, on its own
timeslice. A per-connection *fallback* reader thread covers the windows
when nobody is waiting (pushed task notes, deferred-error notes, one-way
traffic, idle links): it sleeps while a leader holds the connection and
only drains frames that arrive leaderless, so it never steals a reply a
caller could have read inline. Leadership hygiene: a departing leader
first drains every frame already sitting in its buffered reader (a frame
buffered but unread is invisible to the fallback's readiness poll), and a
leader that times out mid-wait releases the socket and promotes a
follower — no frame is lost or delivered twice because exactly one thread
ever reads the connection.

**One-way messages** (:meth:`notify`) carry no request id and expect no
reply: §2.7 read-only-buffering kickoffs, §2.8.4 last-write apply kickoffs,
trailing held-object writes (operation fusion, §2.8), release/terminate
notifications, heartbeats. Server-side failures of one-way ops come back
as ``oneway_err`` *notes* and are recorded per transaction;
:meth:`raise_deferred` surfaces them at the transaction's next sync point
(error deferral, per the paper's asynchrony model: an asynchronous
operation's error belongs to the operation that awaits it).

**Pushed task notes**: when a §2.7/§2.8.4 home-node task completes, the
server pushes a ``task_done`` note on this same connection (piggybacked on
an in-flight reply when one is departing, a standalone push otherwise),
carrying the task's outcome and — when small — the pickled state of the
read buffer it produced. ``join`` of a release task is then a local wait,
and buffered reads execute against the shipped state: usually zero extra
round trips.

Failure mapping (§3.4): any socket-level failure flips the client to
``alive = False`` (crash-stop — a node that vanished is *removed from the
system*), **fails every in-flight future and task wait** so no caller
hangs, and surfaces as :class:`~repro.core.api.RemoteObjectFailure`, which
the transaction machinery already routes through its abort path.

Liveness rides the same link: the connection announces itself with
``mux_hello`` (the server maps it to this process's sessions — the OS
closing it is the instant crash-stop signal), and while this process has
live transactions a daemon thread sends one-way ``heartbeat`` messages
naming them.
"""
from __future__ import annotations

import collections
import itertools
import logging
import random
import select
import socket
import threading
import time
from typing import Any, Dict, List, Optional

from repro.core.api import RemoteObjectFailure
from repro.obs import metrics as _metrics
from repro.obs import txtrace as _txtrace

from .transport import (CLIENT_ID, LocalBuf, TaskWait, Transport, load_buf)
from .wire import (ConnectionClosed, FrameReader, NOTE, OK, WireError,
                   parse_address, recv_msg, send_msg)

log = logging.getLogger("repro.net.client")

#: Mux-connect retry budget: first retry after ~50 ms, doubling to a
#: 500 ms cap, each jittered to 50–150% — worst case well under the
#: failure detector's timeout, so a genuinely dead server still reads as
#: crash-stop promptly.
_CONNECT_ATTEMPTS = 4

# Backwards-compatible aliases: the bookkeeping classes moved to
# repro.net.transport when the Transport interface was carved out.
_LocalBuf = LocalBuf
_TaskWait = TaskWait

#: Fallback reader's yield interval while replies are owed and their
#: about-to-lead callers should read them inline (see _fallback_loop).
FALLBACK_GRACE = 0.002

#: How long a task join waits for the pushed completion note before falling
#: back to an explicit ``task_join`` RPC (covers any lost-push edge case
#: — e.g. a chain-dispensed node that had no client connection to push
#: on — with one bounded round trip instead of a hang).
JOIN_PUSH_GRACE = 1.0


class Future:
    """Completion handle for one in-flight request.

    When issued by :meth:`NodeClient.call_async`, :meth:`result` does not
    merely park on an event — it enters the connection's leader/follower
    protocol, so the waiter reads its own reply inline whenever the
    connection is free.
    """

    __slots__ = ("_done", "_value", "_error", "on_done", "_client", "_mux")

    def __init__(self):
        self._done = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None
        #: invoked (once set) right after completion — the follower wakeup
        #: hook of the leader/follower protocol.
        self.on_done = None
        self._client: Optional["NodeClient"] = None
        self._mux: Optional["_Mux"] = None

    def set_result(self, value: Any) -> None:
        self._value = value
        self._done.set()
        cb = self.on_done
        if cb is not None:
            cb()

    def set_error(self, error: BaseException) -> None:
        self._error = error
        self._done.set()
        cb = self.on_done
        if cb is not None:
            cb()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._done.is_set():
            if self._client is not None and self._mux is not None:
                self._client._await_reply(self._mux, self, timeout)
            else:
                self._done.wait(timeout)
        if not self._done.is_set():
            raise TimeoutError("RPC reply did not arrive in time")
        if self._error is not None:
            raise self._error
        return self._value


class _Mux:
    """One established multiplexed connection.

    ``leader_lock`` is the read-side leadership token: its holder — a
    blocked caller, or the fallback thread during leaderless windows — is
    the only thread that may touch ``reader``. ``lead_free`` mirrors the
    lock for waiters that must park until leadership is released;
    ``followers`` holds the wakeup events of callers parked behind the
    current leader, in arrival order, for promotion on leader exit.
    """

    __slots__ = ("sock", "send_lock", "reader", "leader_lock", "lead_free",
                 "followers", "f_lock", "owed")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.send_lock = threading.Lock()
        self.reader = FrameReader(sock)
        self.leader_lock = threading.Lock()
        self.lead_free = threading.Event()
        self.lead_free.set()
        self.followers: "collections.deque" = collections.deque()
        self.f_lock = threading.Lock()
        #: replies owed on this connection (in-flight request count,
        #: guarded by the client lock) — the fallback reader's signal
        #: that a caller-leader is imminent and the socket is theirs.
        self.owed = 0


class NodeClient(Transport):
    """Multiplexed RPC endpoint for one node server (the TCP transport).

    A small fixed set of mux connections (``conns``) is shared by all
    caller threads with *per-thread affinity*: each thread is pinned to one
    connection, so every message sequence a single transaction produces is
    FIFO on its wire (one-way kickoffs are processed before the requests
    pipelined behind them), while independent client threads get
    independent reader/writer pipelines. The read side of each connection
    is driven by whichever caller is currently awaiting a reply on it
    (leader/follower, see module docstring); the per-connection fallback
    thread only reads during leaderless windows.
    """

    def __init__(self, address: str, *, connect_timeout: float = 5.0,
                 heartbeat_interval: float = 0.5, conns: int = 4):
        super().__init__(address)
        self.host, self.port = parse_address(address)
        self.connect_timeout = connect_timeout
        self.heartbeat_interval = heartbeat_interval
        self._muxes: List[Optional[_Mux]] = [None] * max(1, conns)
        self._tl = threading.local()            # per-thread conn affinity
        self._rr = itertools.count()            # round-robin assignment
        self._conn_lock = threading.Lock()      # connection establishment
        self._req_ids = itertools.count(1)
        self._pending: Dict[int, Future] = {}
        self._hb_thread: Optional[threading.Thread] = None
        self._closed = threading.Event()

    # -- connection ----------------------------------------------------------
    def _mux_for_thread(self) -> _Mux:
        idx = getattr(self._tl, "idx", None)
        if idx is None:
            idx = next(self._rr) % len(self._muxes)
            self._tl.idx = idx
        mux = self._muxes[idx]
        return mux if mux is not None else self._establish(idx)

    def _establish(self, idx: int) -> _Mux:
        with self._conn_lock:
            if self._muxes[idx] is not None:
                return self._muxes[idx]
            if not self.alive or self._closed.is_set():
                raise RemoteObjectFailure(
                    f"node server {self.address} is unreachable (crash-stop)")
            # Transient refusals (backlog overflow, port exhaustion, a
            # server still binding its listener) get a bounded, jittered
            # exponential backoff before the connection counts as dead —
            # jitter decorrelates a thundering herd of clients retrying
            # into the same backlog that just overflowed.
            err: Optional[Exception] = None
            for attempt in range(_CONNECT_ATTEMPTS):
                if attempt:
                    delay = (min(0.05 * (2 ** (attempt - 1)), 0.5)
                             * (0.5 + random.random()))
                    if _txtrace.enabled:
                        self._obs_tracer().instant(
                            "connect_retry",
                            detail=f"{self.address} #{attempt} "
                                   f"+{delay * 1000:.0f}ms",
                            sev=_txtrace.WARN)
                    time.sleep(delay)
                    if not self.alive or self._closed.is_set():
                        break
                try:
                    sock = socket.create_connection(
                        (self.host, self.port), timeout=self.connect_timeout)
                    sock.setsockopt(socket.IPPROTO_TCP,
                                    socket.TCP_NODELAY, 1)
                    # Handshake before any reader exists: announce this
                    # process (the server maps the connection to our
                    # sessions — the drop of our last connection is the
                    # §3.4 instant crash-stop signal) and await the ack on
                    # the still-private socket.
                    send_msg(sock,
                             (0, "mux_hello", {"client_id": self.client_id}))
                    req_id, status, value, _notes = recv_msg(sock)
                    if req_id != 0 or status != OK:
                        raise ConnectionClosed("mux_hello rejected")
                    sock.settimeout(None)   # replies may take long
                    err = None
                    break
                except (OSError, ConnectionClosed, WireError) as e:
                    err = e
            if err is not None:
                # Still refused after the backoff budget. Establishing a
                # *supplementary* connection must not crash-stop the whole
                # client while an established healthy connection exists:
                # re-pin this thread onto one instead.
                for i, mux in enumerate(self._muxes):
                    if mux is not None and self.alive:
                        self._tl.idx = i
                        return mux
                self._mark_dead(f"connect failed: {err}")
                raise RemoteObjectFailure(
                    f"node server {self.address} is unreachable: "
                    f"{err}") from err
            mux = _Mux(sock)
            self._muxes[idx] = mux
            threading.Thread(
                target=self._fallback_loop, args=(mux,),
                name=f"mux-fallback-{self.address}-{idx}", daemon=True).start()
            return mux

    def _send(self, msg: Any) -> _Mux:
        mux = self._mux_for_thread()
        self._send_on(mux, msg)
        return mux

    def _send_on(self, mux: _Mux, msg: Any) -> None:
        try:
            with mux.send_lock:
                send_msg(mux.sock, msg)
        except (OSError, WireError) as e:
            self._mark_dead(f"send failed: {e}")
            raise RemoteObjectFailure(
                f"node server {self.address} failed mid-send: {e}") from e

    # -- read side: leader/follower demux ------------------------------------
    def _dispatch_msg(self, msg: Any, own: Optional[Future] = None,
                      mux: Optional[_Mux] = None) -> None:
        """Demultiplex one inbound message (notes, pushes, replies) to its
        consumers. ``own`` is the dispatching leader's awaited future, for
        the inline-vs-handoff statistics; ``mux`` the connection the
        message arrived on, for its owed-reply account."""
        req_id, status, value, notes = msg
        for note in notes or ():
            self._handle_note(note)
        if req_id is None or status == NOTE:
            return
        with self._lock:
            fut = self._pending.pop(req_id, None)
            if fut is not None:
                if mux is not None and mux.owed > 0:
                    mux.owed -= 1
                if fut is own:
                    self.n_inline += 1
                else:
                    self.n_handoff += 1
        if fut is None:
            # Late reply after a client-side timeout abandoned the
            # call: drop it — the conversation moved on. Recorded as a
            # structured WARN event on the trace (was an ad-hoc warning
            # line), so timeout storms show up per-connection in the
            # merged trace instead of scrolling past on stderr.
            if _txtrace.enabled:
                _txtrace.current().instant(
                    "late_reply", sev=_txtrace.WARN,
                    detail=f"req={req_id} from {self.address}")
            log.debug("dropping reply with unknown request id %r "
                      "from %s (late reply after timeout?)",
                      req_id, self.address)
            return
        if status == OK:
            fut.set_result(value)
        else:
            fut.set_error(value)

    def _await_reply(self, mux: _Mux, fut: Future,
                     timeout: Optional[float]) -> None:
        """Wait for ``fut`` by the leader/follower protocol (the core loop
        is :meth:`_drive`)."""
        self._drive(mux, fut, fut,
                    None if timeout is None else time.monotonic() + timeout)

    def _drive(self, mux: _Mux, waitable: Any, own: Optional[Future],
               deadline: Optional[float]) -> None:
        """The leader/follower core: wait for ``waitable`` (a
        :class:`Future`) by leading the connection's read loop when
        leadership is free, otherwise parking as a follower until
        completion, a departing leader's promotion, or the deadline.
        Returns with the waitable done or the deadline passed.

        Only *reply* waits drive the read loop. Task joins (§2.7/§2.8.4)
        deliberately do not: they are gated on other transactions'
        progress and may park for a long time — a long-lived leader
        funnels every concurrent caller's reply through itself (thread
        handoffs for everyone, measured 3-4x worse under contention).
        RPC waits are short-lived by comparison: leadership turns over at
        every completed reply."""
        is_done = waitable.done
        wake = threading.Event()
        waitable.on_done = wake.set
        if is_done():
            return
        while True:
            if is_done():
                return
            if deadline is not None and time.monotonic() >= deadline:
                return
            if mux.leader_lock.acquire(blocking=False):
                mux.lead_free.clear()
                try:
                    self._lead(mux, is_done, own, deadline)
                finally:
                    mux.leader_lock.release()
                    mux.lead_free.set()
                    self._promote(mux)
                if is_done():
                    return
                continue    # led until timeout (or marked dead): loop exits
            # Follower: park until completion, a promotion, or the
            # deadline. The wait is sliced (0.5 s) so any lost-promotion
            # race heals at the next slice instead of hanging.
            with mux.f_lock:
                mux.followers.append(wake)
            try:
                slice_ = (0.5 if deadline is None
                          else max(0.0, min(0.5, deadline - time.monotonic())))
                wake.wait(slice_)
            finally:
                with mux.f_lock:
                    try:
                        mux.followers.remove(wake)
                    except ValueError:
                        pass    # consumed by a promotion
            wake.clear()

    def _lead(self, mux: _Mux, is_done: Any, fut: Optional[Future],
              deadline: Optional[float]) -> None:
        """Drive the connection's read loop until the awaited completion
        (``is_done``) fires — and the buffered reader holds no further
        frame: a buffered-but-unread frame would be invisible to the
        fallback's readiness poll — or the deadline passes. Exactly one
        thread runs this per connection (the ``leader_lock`` holder), so
        no frame is ever read twice."""
        reader, sock = mux.reader, mux.sock
        while True:
            if reader.has_frame():
                try:
                    self._dispatch_msg(reader.recv_msg(), fut, mux)
                except WireError as e:
                    self._mark_dead(f"connection corrupt: {e}")
                    return
                continue
            if is_done():
                return
            try:
                if deadline is None:
                    # No deadline: block straight in recv (one syscall per
                    # drain) — our reply, or the crash-stop EOF, ends it.
                    self._dispatch_msg(reader.recv_msg(), fut, mux)
                    continue
                wait = deadline - time.monotonic()
                if wait <= 0:
                    return      # timed out as leader: caller promotes
                readable, _, _ = select.select([sock], [], [], wait)
                if not readable:
                    return      # timed out as leader
                self._dispatch_msg(reader.recv_msg(), fut, mux)
            except (ConnectionClosed, WireError, OSError, ValueError) as e:
                if not self._closed.is_set():
                    self._mark_dead(f"connection lost: {e}")
                return

    def _promote(self, mux: _Mux) -> None:
        """Leader handoff: wake the longest-parked follower so it can take
        over the read loop. A lost race (a fresh caller grabs leadership
        first) is harmless — the promoted follower just parks again, and
        the fallback thread is the liveness backstop either way."""
        with mux.f_lock:
            if mux.followers:
                mux.followers.popleft().set()

    def _fallback_loop(self, mux: _Mux) -> None:
        """Reader of last resort: drains frames that arrive while *no
        caller* is awaiting a reply (pushed task notes, deferred-error
        notes, idle links). Parks whenever a leader holds the connection.
        The discriminator is the connection's **owed-reply account**
        (``mux.owed``). While replies are owed, their callers take
        leadership within microseconds — a fallback sitting in select
        would wake for every one of them (a spurious context switch per
        message, the exact cost the demux removes), so it just yields in
        short beats instead, bounded so a never-awaited future cannot
        starve pushes. With nothing owed, arriving data can only be a
        push: the fallback parks in select and delivers it the instant it
        lands (a §2.7 join note must not wait on a poll interval)."""
        sock = mux.sock
        spins = 0
        try:
            while not self._closed.is_set() and self.alive:
                if not mux.lead_free.wait(0.5):
                    continue            # a leader is reading; stay parked
                with self._lock:
                    owed = mux.owed
                if owed > 0 and spins < 25:
                    spins += 1          # a caller-leader is imminent
                    time.sleep(FALLBACK_GRACE)
                    continue
                spins = 0
                readable, _, _ = select.select([sock], [], [], 0.5)
                if not readable:
                    continue
                if not mux.leader_lock.acquire(blocking=False):
                    continue            # a caller beat us to the frames
                mux.lead_free.clear()
                try:
                    while True:
                        if not mux.reader.has_frame():
                            readable, _, _ = select.select([sock], [], [], 0)
                            if not readable:
                                break
                        self._dispatch_msg(mux.reader.recv_msg(), mux=mux)
                finally:
                    mux.leader_lock.release()
                    mux.lead_free.set()
                    self._promote(mux)
        except (ConnectionClosed, WireError, OSError, ValueError) as e:
            if not self._closed.is_set():
                self._mark_dead(f"connection lost: {e}")

    # -- RPC -----------------------------------------------------------------
    def call_async(self, op: str, **kwargs: Any) -> Future:
        """Issue ``op`` without waiting; returns a :class:`Future` whose
        ``result()`` participates in the leader/follower demux."""
        fut = Future()
        mux = self._mux_for_thread()   # may connect; never under the lock
        with self._lock:
            if not self.alive:
                raise RemoteObjectFailure(
                    f"node server {self.address} is unreachable (crash-stop)")
            req_id = next(self._req_ids)
            self._pending[req_id] = fut
            self.n_rpc += 1
            mux.owed += 1   # before the send: the reply may race us back
        try:
            self._send_on(mux, (req_id, op, kwargs))
        except BaseException:
            with self._lock:
                self._pending.pop(req_id, None)
                if mux.owed > 0:
                    mux.owed -= 1
            raise
        fut._mux = mux
        fut._client = self
        return fut

    def call(self, op: str, rpc_timeout: Optional[float] = None,
             **kwargs: Any) -> Any:
        """Invoke ``op`` and wait for its reply (value or re-raised error).

        ``rpc_timeout`` bounds the *wait*, not the server-side execution: on
        expiry the future is abandoned (its late reply will be dropped by
        whoever reads it) and :class:`TimeoutError` raised."""
        if _txtrace.enabled:
            return self._traced_call(op, rpc_timeout, kwargs)
        fut = self.call_async(op, **kwargs)
        try:
            return fut.result(rpc_timeout)
        except TimeoutError:
            with self._lock:
                stale = [rid for rid, f in self._pending.items() if f is fut]
                for rid in stale:
                    del self._pending[rid]
                mux = fut._mux
                if stale and mux is not None and mux.owed > 0:
                    mux.owed -= 1   # its late reply won't settle the account
            raise

    def _traced_call(self, op: str, rpc_timeout: Optional[float],
                     kwargs: Dict[str, Any]) -> Any:
        """``call`` with an ``rpc`` span (client clock domain) — the wire
        side of the tracereport phase decomposition."""
        tr = _txtrace.current()
        t0 = tr.now()
        txn = kwargs.get("txn") or ""
        fut = self.call_async(op, **kwargs)
        try:
            v = fut.result(rpc_timeout)
        except TimeoutError:
            with self._lock:
                stale = [rid for rid, f in self._pending.items() if f is fut]
                for rid in stale:
                    del self._pending[rid]
                mux = fut._mux
                if stale and mux is not None and mux.owed > 0:
                    mux.owed -= 1
            tr.emit("rpc", t0, tr.now() - t0, txn=txn, detail=op,
                    sev=_txtrace.WARN)
            raise
        dur = tr.now() - t0
        tr.emit("rpc", t0, dur, txn=txn, detail=op)
        _metrics.registry(tr.site).histogram("rpc_us").record(dur * 1e6)
        return v

    def notify(self, op: str, **kwargs: Any) -> None:
        """Fire-and-forget one-way message: no reply, errors deferred
        (server reports them as ``oneway_err`` notes; see
        :meth:`raise_deferred`)."""
        self._oneway.inc()   # exact, lock-free (per-thread cells)
        self._send((None, op, kwargs))

    # -- task joins -----------------------------------------------------------
    def join_task(self, txn_uid: str, name: str) -> TaskWait:
        """Join a home-node task: wait briefly for the pushed completion
        note, then fall back to one explicit ``task_join`` RPC.

        Deliberately a plain event wait, NOT a leadership-taking drive: a
        join is gated on OTHER transactions' progress and can park for a
        long time — holding the connection's read leadership that long
        would funnel every concurrent caller's reply through this thread
        (measured 3-4x worse under contention). The note is delivered by
        whichever leader or fallback reads it."""
        wait = self._task_wait(txn_uid, name)
        if not wait.done.wait(JOIN_PUSH_GRACE):
            # No note yet: ask explicitly (blocks server-side until the
            # task completes; re-raises its transactional error).
            res = self.call("task_join", txn=txn_uid, name=name)
            if not wait.done.is_set():
                self.resolve_task(txn_uid, name, None, res.get("buf"))
        return wait

    # -- failure (§3.4 crash-stop) -------------------------------------------
    def _mark_dead(self, reason: str) -> None:
        with self._lock:
            already = not self.alive
            self.alive = False
            muxes = [m for m in self._muxes if m is not None]
            self._muxes = [None] * len(self._muxes)
            pending = list(self._pending.values())
            self._pending.clear()
            waits = list(self._tasks.values())
        if already and not muxes and not pending and not waits:
            return
        err = RemoteObjectFailure(
            f"node server {self.address} is unreachable ({reason})")
        # No waiter hangs: every in-flight future and task join observes
        # the death immediately (leaders and followers wake via on_done).
        for fut in pending:
            fut.set_error(err)
        self._fail_task_waits(waits, err)
        for mux in muxes:
            try:
                mux.sock.close()
            except OSError:
                pass

    def reconnect(self) -> bool:
        """Re-dial a crash-stopped server that restarted at the same
        address (§11). ``_mark_dead`` is final for every in-flight future
        — those stay failed; this only re-opens the transport for NEW
        work once the reborn process is listening. Returns ``True`` iff a
        fresh connection (and mux hello) succeeded."""
        if self._closed.is_set():
            return False
        with self._lock:
            self.alive = True
            self._hb_thread = None   # old loop exited on death; re-armable
        try:
            self._mux_for_thread()
        except RemoteObjectFailure:
            return False             # still down: _establish re-marked dead
        return True

    # -- transaction liveness ------------------------------------------------
    def register_txn(self, txn_uid: str) -> None:
        """Track a live transaction: liveness (hello + heartbeat) rides the
        mux connection."""
        with self._lock:
            self._active_txns.add(txn_uid)
            need_hb = self._hb_thread is None
        self._mux_for_thread()
        if need_hb:
            t = threading.Thread(target=self._heartbeat_loop,
                                 name=f"hb-{self.address}", daemon=True)
            with self._lock:
                if self._hb_thread is None:
                    self._hb_thread = t
                    t.start()

    def _heartbeat_loop(self) -> None:
        while not self._closed.wait(self.heartbeat_interval):
            with self._lock:
                txns = list(self._active_txns)
                alive = self.alive
            if not alive:
                return
            if not txns:
                continue
            try:
                self.notify("heartbeat", client_id=self.client_id, txns=txns)
            except RemoteObjectFailure:
                return             # the mux died; crash-stop already handled

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        self._closed.set()
        with self._lock:
            muxes = [m for m in self._muxes if m is not None]
            self._muxes = [None] * len(self._muxes)
            pending = list(self._pending.values())
            self._pending.clear()
            waits = list(self._tasks.values())
        err = RemoteObjectFailure(f"client for {self.address} closed")
        for fut in pending:
            fut.set_error(err)
        self._fail_task_waits(waits, err)
        for mux in muxes:
            try:
                mux.sock.close()
            except OSError:
                pass

    def __repr__(self) -> str:  # pragma: no cover
        return f"NodeClient({self.address}, alive={self.alive})"
