"""The narrow client-side transport interface (DESIGN.md §3.1, §7).

Everything :mod:`repro.net.remote` needs from a wire is a small surface —
issue a request and await its reply (``call`` / ``call_async``), send a
fire-and-forget one-way (``notify``), join a home-node task, and the
per-transaction bookkeeping that rides on top (deferred one-way errors,
task-completion waits, liveness registration). :class:`Transport` is that
surface plus the transport-*independent* half of the bookkeeping, shared by
its two implementations:

* :class:`repro.net.client.NodeClient` — the real TCP transport: the
  multiplexed pipelined connections, wire-v3 framing, and the
  leader/follower demux all live **below** this interface and stay
  TCP-only;
* :class:`repro.net.simnet.SimTransport` — the deterministic simulation
  transport: frames are delivered directly between in-process endpoints by
  a seeded virtual-time scheduler, no sockets, no reader threads.

What is shared here (identical semantics on every transport):

* the **deferred-error** protocol: server-side failures of one-way
  messages come back as ``oneway_err`` notes, recorded per transaction and
  raised at its next sync point (:meth:`raise_deferred`);
* the **task-note** protocol: §2.7/§2.8.4 home-node task completions
  arrive as ``task_done`` notes (with the read buffer's pickled state
  attached when small — the piggyback read protocol) and resolve local
  :class:`TaskWait` handles;
* transaction liveness bookkeeping (``register_txn`` / ``finish_txn`` /
  ``mark_session_ended``) and the per-transaction message statistics the
  benchmarks report (``n_rpc`` / ``n_oneway`` / ...).
"""
from __future__ import annotations

import logging
import os
import pickle
import socket
import threading
import time
import uuid
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.api import RemoteObjectFailure
from repro.obs import metrics as _metrics
from repro.obs import txtrace as _txtrace

log = logging.getLogger("repro.net.transport")

#: Stable identity of this client *process* across all its transactions.
#: (Simulated client processes carry their own deterministic ids instead.)
CLIENT_ID = f"{socket.gethostname()}:{os.getpid()}:{uuid.uuid4().hex[:6]}"


class LocalBuf:
    """Client-side copy of a home-node read buffer (piggyback protocol).

    Holds the unpickled ``__tx_snapshot__`` state a ``task_done`` note (or a
    ``buffer_snapshot`` reply) shipped because it was small; buffered reads
    then execute locally with zero round trips. Duck-types the ``call``
    surface of :class:`~repro.core.buffers.CopyBuffer`.
    """

    __slots__ = ("state",)

    def __init__(self, state: Any):
        self.state = state

    def call(self, method: str, args: tuple, kwargs: dict) -> Any:
        return getattr(self.state, method)(*args, **kwargs)

    def __repr__(self) -> str:  # pragma: no cover
        return f"LocalBuf({type(self.state).__name__})"


def load_buf(payload: Optional[bytes]) -> Optional[LocalBuf]:
    """Unpickle a piggybacked buffer state; ``None`` stays ``None``."""
    if payload is None:
        return None
    try:
        return LocalBuf(pickle.loads(payload))
    except Exception:  # noqa: BLE001 - class not importable here: read remotely
        return None


class TaskWait:
    """Local completion state of one fire-and-forget home-node task.

    Resolution goes through :meth:`resolve`, which fires the optional
    ``on_done`` hook after setting the event — the same completion shape
    as the TCP client's ``Future``. How a joiner *waits* on ``done`` is the
    transport's business (:meth:`Transport.join_task`).
    """

    __slots__ = ("done", "error", "buf", "on_done")

    def __init__(self):
        self.done = threading.Event()
        self.error: Optional[BaseException] = None
        self.buf: Optional[LocalBuf] = None
        self.on_done = None

    def resolve(self) -> None:
        self.done.set()
        cb = self.on_done
        if cb is not None:
            cb()


class _PerThreadCounter:
    """Exact multi-threaded counter with lock-free increments: every
    thread bumps a private cell (registered once, under the lock); reads
    sum the cells. The bench's ``c.n_oneway = 0`` reset-by-assignment
    folds into ``base`` via :meth:`set`. This replaces the former bare
    ``self.n_oneway += 1`` — an unlocked read-modify-write that could
    drop increments when pipelined writers raced the client thread,
    skewing the exact sim gate's per-txn message counts."""

    __slots__ = ("_lock", "_cells", "_tl", "_base")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._cells: List[List[int]] = []
        self._tl = threading.local()
        self._base = 0

    def inc(self) -> None:
        c = getattr(self._tl, "c", None)
        if c is None:
            c = [0]
            with self._lock:
                self._cells.append(c)
            self._tl.c = c
        c[0] += 1

    def value(self) -> int:
        with self._lock:
            return self._base + sum(c[0] for c in self._cells)

    def set(self, v: int) -> None:
        with self._lock:
            for c in self._cells:
                c[0] = 0
            self._base = v


class Transport:
    """Abstract client-side transport to ONE home node (see module doc).

    Subclasses implement the message-moving primitives (``call_async``,
    ``notify``, ``join_task``, ``register_txn``, ``close``) and share the
    transaction-scoped bookkeeping implemented here. All shared state is
    guarded by ``self._lock``, which subclasses may also use for their own
    state (the TCP client does — one lock, exactly as before the split).
    """

    #: short transport-scheme tag; part of the dispense-domain sort key
    #: (must be identical for every client talking to the same node).
    scheme = "tcp"

    def __init__(self, address: str, client_id: str = CLIENT_ID):
        self.address = address
        self.client_id = client_id
        self.alive = True
        #: optional locality hint (a node address) shipped with dispense
        #: batches — feeds the home node's per-object access-affinity
        #: counters that drive lease migration (DESIGN.md §10).
        self.affinity: Optional[str] = None
        self._lock = threading.Lock()
        self._tasks: Dict[Tuple[str, str], TaskWait] = {}
        self._deferred: Dict[str, List[BaseException]] = {}
        self._active_txns: Set[str] = set()
        self._ended: Set[str] = set()           # server already dropped these
        # -- transport statistics (per-txn wire metrics in the bench) --------
        self.n_rpc = 0          # round-trip requests issued
        self._oneway = _PerThreadCounter()   # one-ways: see n_oneway property
        self.n_inline = 0       # replies read by their own awaiting caller
        self.n_handoff = 0      # replies delivered across a thread handoff

    # -- abstract message primitives -----------------------------------------
    def call_async(self, op: str, **kwargs: Any):
        """Issue ``op`` without waiting; returns a future with
        ``result(timeout)`` / ``done()`` semantics."""
        raise NotImplementedError

    @property
    def n_oneway(self) -> int:
        """One-way messages sent — exact under concurrency (per-thread
        cells, summed here; the bench's ``c.n_oneway = 0`` reset goes
        through the setter)."""
        return self._oneway.value()

    @n_oneway.setter
    def n_oneway(self, v: int) -> None:
        self._oneway.set(v)

    def _obs_tracer(self):
        """Site for this transport's client-side rpc spans — the calling
        thread's bound tracer by default; the sim transport overrides the
        fallback so even setup-phase calls read the virtual clock."""
        return _txtrace.current()

    def call(self, op: str, rpc_timeout: Optional[float] = None,
             **kwargs: Any) -> Any:
        """Invoke ``op`` and wait for its reply (value or re-raised error)."""
        if _txtrace.enabled:
            tr = self._obs_tracer()
            t0 = tr.now()
            v = self.call_async(op, **kwargs).result(rpc_timeout)
            dur = tr.now() - t0
            tr.emit("rpc", t0, dur, txn=kwargs.get("txn") or "", detail=op)
            _metrics.registry(tr.site).histogram("rpc_us").record(dur * 1e6)
            return v
        return self.call_async(op, **kwargs).result(rpc_timeout)

    def notify(self, op: str, **kwargs: Any) -> None:
        """Fire-and-forget one-way message: no reply, errors deferred."""
        raise NotImplementedError

    def join_task(self, txn_uid: str, name: str) -> TaskWait:
        """Block until the named home-node task's completion is known
        locally; returns its resolved :class:`TaskWait` (the caller
        re-raises ``wait.error``)."""
        raise NotImplementedError

    def register_txn(self, txn_uid: str) -> None:
        """Track a live transaction (presence + heartbeat setup)."""
        raise NotImplementedError

    def sleep(self, seconds: float) -> None:
        """Transport-clocked backoff (failover promote retries): real time
        on TCP, virtual time under the simulation transport."""
        time.sleep(seconds)

    def failover_grace(self) -> float:
        """Failure-detection grace before promoting a follower or querying
        a decision ledger (DESIGN.md §8): one detection period >> the
        maximum one-way latency, so every frame a dead primary queued
        before crashing has landed by promotion time. Transport-supplied
        so the simulation derives it from its *virtual* link latencies
        instead of a wall-clock constant."""
        return 0.05

    def close(self) -> None:
        raise NotImplementedError

    # -- deferred errors and task notes (shared) ------------------------------
    def raise_deferred(self, txn_uid: str) -> None:
        """Sync point: raise the first deferred one-way error of ``txn_uid``
        recorded since the last sync point, if any."""
        with self._lock:
            errors = self._deferred.pop(txn_uid, None)
        if errors:
            raise errors[0]

    def _task_wait(self, txn_uid: str, name: str) -> TaskWait:
        with self._lock:
            return self._tasks.setdefault((txn_uid, name), TaskWait())

    def task_wait(self, txn_uid: str, name: str) -> TaskWait:
        """The local completion handle of a fire-and-forget home-node task
        (created on kickoff, resolved by the pushed ``task_done`` note, a
        carrier reply via :meth:`resolve_task`, or transport death)."""
        return self._task_wait(txn_uid, name)

    def resolve_task(self, txn_uid: str, name: str,
                     error: Optional[BaseException],
                     buf: Optional[bytes]) -> None:
        """Resolve a task wait from a result that rode back on a carrier
        reply (e.g. an inline-completed §2.7 task on the dispense reply)."""
        wait = self._task_wait(txn_uid, name)
        wait.error = error
        wait.buf = load_buf(buf)
        wait.resolve()

    def _handle_note(self, note: Dict[str, Any]) -> None:
        """Process one server note (``task_done`` / ``oneway_err``) —
        identical protocol on every transport."""
        kind = note.get("kind")
        if kind == "task_done":
            key = (note["txn"], note["name"])
            with self._lock:
                if note["txn"] not in self._active_txns:
                    log.debug("dropping task note for finished txn %r", key)
                    return
                wait = self._tasks.setdefault(key, TaskWait())
            wait.error = note.get("error")
            wait.buf = load_buf(note.get("buf"))
            wait.resolve()
        elif kind == "oneway_err":
            txn = note.get("txn")
            err = note.get("error") or RuntimeError("one-way op failed")
            log.debug("deferred one-way error for txn %r op %r: %r",
                      txn, note.get("op"), err)
            if txn is None:
                return
            with self._lock:
                active = txn in self._active_txns
                if active:
                    self._deferred.setdefault(txn, []).append(err)
            if not active:
                # Arrived after the transaction finished locally (e.g. a
                # pipelined step-5 terminate racing a §3.4 expiry): there
                # is no sync point left to raise it at — the epoch
                # machinery keeps the system consistent, but make the
                # partial termination visible as a structured WARN event
                # on the trace (severity-tagged, correlated to the txn)
                # instead of an ad-hoc stderr line.
                if _txtrace.enabled:
                    _txtrace.current().instant(
                        "oneway_err", txn=txn or "",
                        detail=f"{note.get('op')}: {err!r}"[:120],
                        sev=_txtrace.WARN)
                log.debug("one-way %r failed for finished txn %r: %r",
                          note.get("op"), txn, err)
                return
            # A failed kickoff never produces a completion note: fail the
            # task wait too, or its joiner would hang forever.
            if note.get("op") in ("ro_buffer", "lw_apply") and note.get("name"):
                wait = self._task_wait(txn, note["name"])
                wait.error = err
                wait.resolve()
        else:  # pragma: no cover - forward compatibility
            log.warning("ignoring unknown note kind %r from %s",
                        kind, self.address)

    # -- transaction lifecycle (shared) ---------------------------------------
    def mark_session_ended(self, txn_uid: str) -> None:
        """The server already dropped this session (``finish_batch`` with
        ``end``): :meth:`finish_txn` skips its trailing ``end_txn``."""
        with self._lock:
            self._ended.add(txn_uid)

    def finish_txn(self, txn_uid: str) -> None:
        """The transaction terminated everywhere: drop the server session
        and every local trace of the transaction."""
        with self._lock:
            if txn_uid not in self._active_txns:
                return
            self._active_txns.discard(txn_uid)
            self._deferred.pop(txn_uid, None)
            ended = txn_uid in self._ended
            self._ended.discard(txn_uid)
            for key in [k for k in self._tasks if k[0] == txn_uid]:
                del self._tasks[key]
        if ended:
            return
        try:
            self.notify("end_txn", txn=txn_uid)
        except RemoteObjectFailure:
            pass  # server is gone; nothing left to clean up there

    def _fail_task_waits(self, waits, err: BaseException) -> None:
        """Resolve unfinished task waits with ``err`` (crash-stop: no
        joiner may hang on a vanished server)."""
        for w in waits:
            if not w.done.is_set():
                w.error = err
                w.resolve()
