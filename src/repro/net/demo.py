"""Demo shared-object classes for the distributed examples and tests.

Objects bound over the wire are pickled *by reference* (class path), so the
node server must be able to import their class. Classes defined in a
``__main__`` script can't be imported remotely — the distributed quickstart
and transport tests use these instead.
"""
from __future__ import annotations

import time

from repro.core import Mode, access


class Account:
    """The paper's bank account (Fig. 7), with declared access modes."""

    def __init__(self, balance: int = 0):
        self.bal = balance

    @access(Mode.READ)
    def balance(self) -> int:
        return self.bal

    @access(Mode.UPDATE)
    def deposit(self, v: int) -> None:
        self.bal += v

    @access(Mode.UPDATE)
    def withdraw(self, v: int) -> None:
        self.bal -= v

    @access(Mode.WRITE)
    def reset(self) -> None:
        self.bal = 0

    def __tx_snapshot__(self) -> "Account":
        return Account(self.bal)


class GuardedAccount(Account):
    """Account whose withdrawals enforce a non-negative balance — gives
    transport tests a method that *raises* mid-transaction (e.g. in the
    middle of a fused ``txn_call_batch``)."""

    @access(Mode.UPDATE)
    def withdraw(self, v: int) -> int:
        if v > self.bal:
            raise ValueError(f"insufficient funds: {v} > {self.bal}")
        self.bal -= v
        return self.bal

    def __tx_snapshot__(self) -> "GuardedAccount":
        return GuardedAccount(self.bal)


class LedgerAccount(Account):
    """Account that also keeps an append-only mark ledger.

    ``mark`` is a pure WRITE (no read of state): a write-only transaction
    on it takes the §2.8.4 path — client-side log buffering, one-way
    ``lw_apply`` kickoff, asynchronous apply+release on the home node.
    The seed-sweep fuzzer uses unique per-transaction tags to check the
    exactly-once invariant: a committed mark appears exactly once, an
    aborted or crashed one never (no lost writes, no double applies, no
    dead transaction's log applied)."""

    def __init__(self, balance: int = 0):
        super().__init__(balance)
        self.marks = []

    @access(Mode.WRITE)
    def mark(self, tag) -> None:
        self.marks.append(tag)

    @access(Mode.READ)
    def read_marks(self):
        return list(self.marks)

    def __tx_snapshot__(self) -> "LedgerAccount":
        c = LedgerAccount(self.bal)
        c.marks = list(self.marks)
        return c


class HotAccount(Account):
    """Account whose deposits form a commuting method class (DESIGN.md §12).

    ``deposit`` invocations from commute-restricted transactions skip
    version-gated dispensing and merge as deltas at the home node; exact
    accesses (``balance``, ``withdraw``) snap the object back to full
    OptSVA ordering."""

    @access(Mode.WRITE, commutes="deposit")
    def deposit(self, v: int) -> None:
        self.bal += v

    def __tx_snapshot__(self) -> "HotAccount":
        return HotAccount(self.bal)


class HotLedgerAccount(LedgerAccount):
    """LedgerAccount whose deposits form a commuting method class (§12).

    The seed-sweep fuzzer's commute mode binds these: commute-restricted
    transfers ship both deposit legs as mergeable deltas (one positive,
    one negative — the sum is conserved even when the deltas fold under
    the merge lock), while marks, audits, and exact transfers keep the
    full version-gated path and force snap-backs mid-sweep."""

    @access(Mode.WRITE, commutes="deposit")
    def deposit(self, v: int) -> None:
        self.bal += v

    def __tx_snapshot__(self) -> "HotLedgerAccount":
        c = HotLedgerAccount(self.bal)
        c.marks = list(self.marks)
        return c


class SlowAccount(Account):
    """Account whose operations take ``op_time`` seconds at the home node —
    makes CF delegation visible in timings."""

    def __init__(self, balance: int = 0, op_time: float = 0.0):
        super().__init__(balance)
        self.op_time = op_time

    @access(Mode.READ)
    def balance(self) -> int:
        if self.op_time:
            time.sleep(self.op_time)
        return self.bal

    def __tx_snapshot__(self) -> "SlowAccount":
        return SlowAccount(self.bal, self.op_time)
