"""Deterministic sharded token pipeline.

Synthetic LM data with a Zipf-ish unigram distribution plus induced bigram
structure (so a model can actually reduce loss), generated as a pure
function of ``(seed, step)`` — the pipeline is *stateless*, which makes
checkpoint/restart and elastic rescaling exact: the data cursor in the
transactional store is just the step counter, and any reshaped cluster can
regenerate precisely the batches it owes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    enc_seq: int = 0          # >0 for enc-dec models (whisper stub frames)
    enc_dim: int = 0


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step]))


def make_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Pure function of (config, step) -> one global batch."""
    rng = _batch_rng(cfg, step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
    # Zipf-ish unigram draw, then overwrite with structure: even positions
    # seed a bigram chain t[i+1] = (a*t[i] + c) % V so loss can fall.
    ranks = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
    tokens = np.minimum(ranks, V - 1).astype(np.int32)
    a, c = 31, 17
    chain = (a * tokens[:, :-1] + c) % V
    mask = (np.arange(S) % 2 == 1)
    tokens[:, 1:][:, mask] = chain[:, mask].astype(np.int32)
    batch = {"tokens": tokens[:, :S],
             "labels": tokens[:, 1:S + 1]}
    if cfg.enc_seq:
        batch["enc_frames"] = rng.standard_normal(
            (B, cfg.enc_seq, cfg.enc_dim), dtype=np.float32)
    return batch


class Pipeline:
    """Iterator facade with an explicit, restorable cursor."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = make_batch(self.cfg, self.step)
        self.step += 1
        return batch

    def restore(self, step: int) -> None:
        self.step = step
