from .pipeline import DataConfig, Pipeline, make_batch
__all__ = ["DataConfig", "Pipeline", "make_batch"]
