"""Versioned sharded checkpoint store.

Layout::

    <dir>/step_<N>/manifest.json       # leaf paths, shapes, dtypes, version
    <dir>/step_<N>/<leaf-hash>.npy     # one array per pytree leaf
    <dir>/LATEST                       # atomic pointer (rename-committed)

Writes are crash-safe: the step directory is written under a temp name and
atomically renamed, then LATEST is updated by rename — a torn write can
never be observed, mirroring the "no object observed mid-transaction"
guarantee the control plane gives in-process. Save runs inside an
*irrevocable read-only* OptSVA-CF transaction when coordinated through
``repro.txstore`` (file I/O must never be re-executed; paper §2.4).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

Params = Any


def _leaf_key(path: Tuple) -> str:
    names = [p.key if hasattr(p, "key") else str(getattr(p, "idx", p))
             for p in path]
    return "/".join(names)


class CheckpointStore:
    def __init__(self, directory: str):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------ #
    def save(self, tree: Params, step: int) -> str:
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        tmp = Path(tempfile.mkdtemp(dir=self.dir, prefix=f".tmp_step_{step}_"))
        manifest: Dict[str, Any] = {"step": step, "leaves": {}}
        try:
            for path, leaf in leaves:
                key = _leaf_key(path)
                fname = hashlib.sha1(key.encode()).hexdigest()[:16] + ".npy"
                arr = np.asarray(leaf)
                np.save(tmp / fname, arr)
                manifest["leaves"][key] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": str(arr.dtype)}
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
            final = self.dir / f"step_{step}"
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)                      # atomic commit
            self._set_latest(step)
            return str(final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    def _set_latest(self, step: int) -> None:
        ptr = self.dir / "LATEST"
        tmp = self.dir / ".LATEST.tmp"
        tmp.write_text(str(step))
        os.rename(tmp, ptr)                            # atomic pointer swap

    # ------------------------------------------------------------------ #
    def latest_step(self) -> Optional[int]:
        ptr = self.dir / "LATEST"
        if not ptr.exists():
            return None
        step = int(ptr.read_text().strip())
        if not (self.dir / f"step_{step}" / "manifest.json").exists():
            return None  # torn directory (crash between renames): ignore
        return step

    def restore(self, template: Params, step: Optional[int] = None,
                *, shardings: Optional[Params] = None) -> Tuple[Params, int]:
        """Load into the template's treedef; optionally device_put with new
        shardings (elastic restore onto a different mesh)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError("no checkpoint available")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        out: List[Any] = []
        sh_leaves = (jax.tree_util.tree_leaves(shardings)
                     if shardings is not None else [None] * len(leaves))
        for (path, leaf), sh in zip(leaves, sh_leaves):
            key = _leaf_key(path)
            meta = manifest["leaves"][key]
            arr = np.load(d / meta["file"])
            assert list(arr.shape) == meta["shape"]
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), out)
        return tree, step

    def gc(self, keep: int = 3) -> None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.dir.glob("step_*"))
        for s in steps[:-keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)


class AsyncCheckpointer:
    """Background checkpoint writer fed by transactional snapshots.

    ``submit`` is called with an already-consistent snapshot (taken by the
    txstore's irrevocable read-only transaction); the file I/O happens on
    this thread so the trainer never blocks on disk.
    """

    def __init__(self, store: CheckpointStore,
                 on_done: Optional[Callable[[int, str], None]] = None):
        self.store = store
        self.on_done = on_done
        self._lock = threading.Lock()
        self._pending: Optional[Tuple[Params, int]] = None
        self._busy = False                 # a save is in flight on the thread
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._idle = threading.Condition(self._lock)
        self.saved: List[int] = []
        self.errors: List[str] = []
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="async-ckpt")
        self._thread.start()

    def submit(self, tree: Params, step: int) -> None:
        with self._lock:
            self._pending = (tree, step)   # newest wins; older snap dropped
        self._wake.set()

    def _loop(self) -> None:
        while not self._stop.is_set():
            self._wake.wait(timeout=0.2)
            self._wake.clear()
            with self._lock:
                job, self._pending = self._pending, None
                self._busy = job is not None
            if job is None:
                continue
            tree, step = job
            try:
                path = self.store.save(tree, step)
                self.saved.append(step)
                if self.on_done:
                    self.on_done(step, path)
            except BaseException as e:  # noqa: BLE001
                self.errors.append(repr(e))
            finally:
                with self._lock:
                    self._busy = False
                    self._idle.notify_all()

    def drain(self, timeout: float = 60.0) -> None:
        """Block until every submitted snapshot is fully on disk — i.e. no
        job is pending AND no save is in flight (a drain that returns while
        the last save is mid-write lets callers observe the previous
        LATEST pointer)."""
        self._wake.set()
        with self._lock:
            self._idle.wait_for(
                lambda: self._pending is None and not self._busy,
                timeout=timeout)

    def stop(self) -> None:
        self.drain()
        self._stop.set()
        self._wake.set()
        self._thread.join(timeout=10.0)
