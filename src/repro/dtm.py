"""``repro.dtm`` — the one-stop public API of the transactional memory.

The canonical surface of the OptSVA-CF reproduction (DESIGN.md §12): one
import gives everything an application needs across all three transports
(in-process, TCP, deterministic simulation)::

    from repro.dtm import (access, Mode, Suprema, Transaction, Registry,
                           connect, bind, spawn_server)
    from repro.net.demo import HotAccount   # wire-bound classes must be
                                            # importable (pickled by ref)

    server = spawn_server("node0")                  # one process per node
    reg = connect(server.address)                   # client-side registry
    node, = reg.nodes
    bind(node, "hot", HotAccount(0))

    t = Transaction(reg)
    acct = t.commutes(reg.locate("hot"))            # commute-restricted
    t.start(lambda _t: acct.deposit(10))            # merges as a delta

Everything here is a re-export or a thin veneer over ``repro.core`` and
``repro.net``; the implementation modules remain importable (legacy public
paths keep working — deprecated forms warn exactly once and point here).
"""
from __future__ import annotations

from typing import Any, Optional

from repro.core import (AbortError, Mode, Registry, RemoteObjectFailure,
                        Suprema, Transaction, TransactionError, access)
from repro.net.spawn import spawn_server

__all__ = [
    "access", "Mode", "Suprema", "Transaction", "Registry",
    "connect", "bind", "spawn_server",
    # the error surface applications handle
    "AbortError", "TransactionError", "RemoteObjectFailure",
]


def connect(*addresses: str, registry: Optional[Registry] = None,
            **client_kw: Any) -> Registry:
    """Build (or extend) a client-side :class:`Registry` connected to node
    servers.

    Each ``address`` is ``"host:port"`` (TCP) or a transport-specific
    address such as ``"sim://node0"``. Returns the registry; the connected
    nodes are reachable through ``registry.nodes`` and their bindings
    through ``registry.locate``.
    """
    reg = registry if registry is not None else Registry()
    for address in addresses:
        reg.connect(address, **client_kw)
    return reg


def bind(node: Any, name: str, obj: Any, *, followers: tuple = (),
         wal: Any = None, lease: Any = None) -> Any:
    """Publish ``obj`` under ``name`` on ``node`` — the unified publish
    signature (DESIGN.md §12).

    ``node`` may be an in-process :class:`~repro.core.registry.Node`, a
    connected :class:`~repro.net.remote.RemoteNode`, or a simulation node
    proxy — all expose the same keyword-only ``bind``. ``followers``
    (replica chain), ``wal`` (durability) and ``lease`` (ownership) are
    node-server publish options; the in-process registry accepts only
    their defaults.
    """
    return node.bind(name, obj, followers=followers, wal=wal, lease=lease)
