"""launch subpackage."""
