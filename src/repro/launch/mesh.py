"""Production meshes.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS *before* first jax
init and only then builds meshes.

Single pod: ``(data=16, model=16)`` — 256 chips (one v5e pod).
Multi-pod:  ``(pod=2, data=16, model=16)`` — 512 chips across DCN; the
``pod`` axis carries pure data parallelism (gradient all-reduce over DCN),
``data`` carries ZeRO sharding, ``model`` carries TP/EP.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, dp: int = 1, tp: int = 1) -> jax.sharding.Mesh:
    """Small mesh for local smoke runs (defaults to the single CPU device)."""
    return jax.make_mesh((dp, tp), ("data", "model"))


def dp_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """The batch-sharding axes: ('pod','data') on multi-pod, ('data',) else."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_size(mesh: jax.sharding.Mesh) -> int:
    return mesh.shape.get("model", 1)
