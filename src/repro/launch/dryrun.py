import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be run as a fresh process (``python -m repro.launch.dryrun``): the
XLA_FLAGS line above executes before any jax import so the host platform
exposes 512 placeholder devices for the production meshes.

For each cell this:
  1. builds the arch's Backbone with the production PartitionPlan,
  2. constructs ShapeDtypeStruct input specs (no allocation),
  3. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``,
  4. records memory_analysis / cost_analysis / parsed collective bytes
     into results/dryrun/<cell>.json (incremental; --force to redo).

``long_500k`` is skipped for pure-full-attention archs (see DESIGN.md §4)
and recorded as {"skipped": reason}.
"""
import argparse
import json
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import hlocost
from repro.launch import roofline as rl
from repro.launch.mesh import dp_axes, make_production_mesh, tp_size
from repro.launch.shardings import (batch_shardings, cache_shardings,
                                    make_param_gatherer, make_sharder,
                                    param_shardings)
from repro.models import SHAPES, Backbone, PartitionPlan, get_config
from repro.models.config import ARCH_NAMES, ShapeConfig
from repro.optim import adamw
from repro.runtime.steps import (StepSettings, make_decode_step,
                                 make_prefill_step, make_train_step,
                                 train_state_specs)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# long_500k policy (DESIGN.md §4): run only where the KV footprint is bounded
LONG_OK = {"rwkv6-3b", "mixtral-8x22b", "recurrentgemma-9b"}


def cell_skip_reason(arch: str, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and arch not in LONG_OK:
        return ("full-attention KV cache at 524288 would be unbounded; "
                "sub-quadratic archs only (DESIGN.md §4)")
    return None


def _spec_like(tree, shardings):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shardings)


def build_cell(arch: str, shape: ShapeConfig, mesh, *,
               settings: StepSettings):
    """Returns (jitted_fn, example_args_specs)."""
    from repro.launch.shardings import effective_dp, full_dp_active
    cfg = get_config(arch)
    fdp = full_dp_active(cfg, mesh, shape.global_batch)
    plan = PartitionPlan(tp=1 if fdp else tp_size(mesh))
    dp = effective_dp(cfg, mesh, shape.global_batch)
    serve = shape.kind != "train"
    gatherer = (make_param_gatherer(cfg, mesh, full_dp=fdp)
                if (settings.gather_weights and settings.zero3
                    and not serve) else None)
    bb = Backbone(cfg, plan,
                  compute_dtype=jnp.bfloat16,
                  param_dtype=jnp.bfloat16 if serve else jnp.float32,
                  remat=settings.remat and not serve,
                  remat_policy=settings.remat_policy,
                  sharder=make_sharder(cfg, mesh,
                                       batch_sharded=shape.global_batch > 1,
                                       global_batch=shape.global_batch),
                  param_gather=gatherer,
                  moe_impl="ep" if settings.moe_ep else "gspmd",
                  mesh=mesh,
                  dp_axes=dp if shape.global_batch > 1 else ())
    p_sh = param_shardings(bb, mesh, zero3=settings.zero3, full_dp=fdp)
    B, S = shape.global_batch, shape.seq_len
    bsh = batch_shardings(cfg, shape, mesh, batch_sharded=B > 1)
    dpspec = (dp or None) if B > 1 else None

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        step = make_train_step(bb, opt_cfg, settings)
        state_specs = train_state_specs(bb, settings)
        state_sh = {
            "params": p_sh,
            "opt": {"step": NamedSharding(mesh, P()),
                    "m": p_sh, "v": p_sh},
        }
        if settings.compress_grads:
            state_sh["error"] = p_sh
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if cfg.is_enc_dec:
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        jfn = jax.jit(step, in_shardings=(state_sh, bsh),
                      donate_argnums=(0,))
        args = (_spec_like(state_specs, state_sh),
                _spec_like(batch, bsh))
        return jfn, args

    param_specs = bb.param_specs()
    if shape.kind == "prefill":
        step = make_prefill_step(bb, ctx=S)
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.is_enc_dec:
            batch["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        jfn = jax.jit(step, in_shardings=(p_sh, bsh))
        return jfn, (_spec_like(param_specs, p_sh), _spec_like(batch, bsh))

    # decode
    step = make_decode_step(bb)
    c_sh = cache_shardings(bb, mesh, B)
    cache_specs = jax.eval_shape(lambda: bb.init_cache(B, S))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, P(dpspec, None))
    jfn = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh),
                  donate_argnums=(1,))
    return jfn, (_spec_like(param_specs, p_sh),
                 _spec_like(cache_specs, c_sh),
                 jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=tok_sh))


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             settings: StepSettings, verbose: bool = True) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = 1
    for a in mesh.axis_names:
        n_chips *= mesh.shape[a]
    t0 = time.time()
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": n_chips, "settings": settings.__dict__,
    }
    reason = cell_skip_reason(arch, shape)
    if reason:
        result["skipped"] = reason
        return result
    with mesh:
        jfn, args = build_cell(arch, shape, mesh, settings=settings)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # older jax: [dict] per device
        cost = cost[0] if cost else {}
    elif cost is None:
        cost = {}
    hlo = compiled.as_text()
    totals = hlocost.analyze(hlo)       # trip-count-aware (source of record)

    cfg = get_config(arch)
    plan = PartitionPlan(tp=tp_size(mesh))
    bb = Backbone(cfg, plan)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mflops = rl.model_flops(bb, shape.kind, tokens)
    terms = rl.derive_terms_from_totals(totals, mflops, n_chips)

    result.update({
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost_analysis_raw": {"flops": cost.get("flops"),
                              "bytes_accessed": cost.get("bytes accessed")},
        "hlocost": totals.to_json(),
        "roofline": terms.to_json(),
    })
    if verbose:
        m = result["memory"]
        print(f"[{arch} × {shape_name} × {mesh_kind}] "
              f"compile={t_compile:.1f}s "
              f"peak/dev={(m['peak_bytes'] or 0)/2**30:.2f}GiB "
              f"flops/dev={terms.hlo_flops:.3e} "
              f"coll/dev={totals.collective_bytes/2**20:.1f}MiB "
              f"(in-loop {totals.in_loop_count:.0f} ops) "
              f"dominant={terms.dominant} "
              f"frac={terms.roofline_fraction:.3f}",
              flush=True)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--zero3", type=int, default=1)
    ap.add_argument("--gather-weights", type=int, default=1)
    ap.add_argument("--remat", type=int, default=1)
    ap.add_argument("--compress-grads", type=int, default=0)
    ap.add_argument("--moe-ep", type=int, default=1)
    ap.add_argument("--remat-policy", default="full", choices=["full", "dots"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="", help="suffix for result files")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    settings = StepSettings(zero3=bool(args.zero3),
                            gather_weights=bool(args.gather_weights),
                            remat=bool(args.remat),
                            compress_grads=bool(args.compress_grads),
                            remat_policy=args.remat_policy,
                            moe_ep=bool(args.moe_ep),
                            microbatches=args.microbatches)
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"-{args.tag}" if args.tag else ""
                out = RESULTS_DIR / f"{arch}--{shape}--{mesh_kind}{tag}.json"
                if out.exists() and not args.force:
                    print(f"skip (exists): {out.name}", flush=True)
                    continue
                try:
                    res = run_cell(arch, shape, mesh_kind, settings=settings)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((arch, shape, mesh_kind, repr(e)))
                    res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "error": repr(e)}
                out.write_text(json.dumps(res, indent=2))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nDRY-RUN COMPLETE: all requested cells lowered & compiled.")


if __name__ == "__main__":
    main()
