"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh) cell, all in seconds-per-step on the
TARGET hardware (TPU v5e-class constants; this container is CPU-only so we
derive from the compiled module, never from wall time):

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

``cost_analysis()`` of a GSPMD-partitioned executable reports the per-device
module, so no extra division by chip count is applied. Collective bytes are
not in cost_analysis: we parse the partitioned HLO text and sum output-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, tracking which computation (scan body vs top level) each
lives in — the "inside-scan" count is how we verify the early-release
schedule actually moved collectives into the layer loop.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

# ---- hardware constants (TPU v5e-class, per chip) --------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (assignment constant)

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s4": 1, "u4": 1,  # rounded up
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_total: int = 0
    count: int = 0
    by_op: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    by_op_count: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    in_loop_bytes: int = 0
    in_loop_count: int = 0

    def to_json(self) -> Dict[str, Any]:
        return {
            "bytes_total": self.bytes_total,
            "count": self.count,
            "by_op": dict(self.by_op),
            "by_op_count": dict(self.by_op_count),
            "in_loop_bytes": self.in_loop_bytes,
            "in_loop_count": self.in_loop_count,
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output bytes of collective ops in a partitioned HLO module."""
    stats = CollectiveStats()
    current_comp = ""
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # computation headers look like:  %body.123 (param...) -> ... {
        if stripped.endswith("{") and ("(" in stripped) and "=" not in stripped.split("(")[0]:
            head = stripped.split("(")[0].strip()
            current_comp = head.lstrip("%")
            continue
        for op in COLLECTIVE_OPS:
            token = f" {op}("
            alt = f" {op}-start("
            if token not in stripped and alt not in stripped:
                continue
            # output shapes appear between '=' and the op name
            eq = stripped.find("=")
            opi = stripped.find(token)
            if opi < 0:
                opi = stripped.find(alt)
            if eq < 0 or opi < eq:
                continue
            out_region = stripped[eq + 1: opi]
            nbytes = sum(_shape_bytes(d, s)
                         for d, s in _SHAPE_RE.findall(out_region))
            stats.bytes_total += nbytes
            stats.count += 1
            stats.by_op[op] += nbytes
            stats.by_op_count[op] += 1
            comp = current_comp.lower()
            if ("while" in comp or "body" in comp or "cond" in comp
                    or "scan" in comp):
                stats.in_loop_bytes += nbytes
                stats.in_loop_count += 1
            break
    return stats


# --------------------------------------------------------------------------- #
# MODEL_FLOPS (the "useful work" yardstick)                                    #
# --------------------------------------------------------------------------- #
def active_param_count(bb) -> Tuple[int, int]:
    """(N_active_nonembed, N_total) from the parameter tree.

    MoE expert leaves are scaled by top_k/n_experts for the active count.
    Embedding table excluded from N_active (a gather, not a matmul); the
    LM head term is added separately by model_flops().
    """
    import jax

    cfg = bb.cfg
    specs = bb.param_specs()
    n_active = 0
    n_total = 0
    moe_frac = (cfg.top_k / cfg.n_experts) if cfg.n_experts else 1.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
        names = [p.key if hasattr(p, "key") else str(p) for p in path]
        size = 1
        for d in leaf.shape:
            size *= d
        n_total += size
        if "embed" in names or names[-1] == "lm_head":
            continue
        if cfg.ffn_kind == "moe" and len(leaf.shape) == 4 \
                and names[-1] in ("w_gate", "w_up", "w_down"):
            n_active += int(size * moe_frac)
        else:
            n_active += size
    return n_active, n_total


def model_flops(bb, shape_kind: str, tokens: int) -> float:
    """6·N_active·tokens (train) or 2·N_active·tokens (serve), plus the
    LM-head matmul term 6/2·tokens·d·V."""
    n_active, _ = active_param_count(bb)
    head = bb.cfg.d_model * bb.plan.eff_vocab(bb.cfg)
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * tokens * (n_active + head)


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float          # global useful FLOPs per step
    hlo_flops: float            # per-device compiled FLOPs
    useful_ratio: float         # (model_flops / chips) / hlo_flops
    n_chips: int = 1

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time over the binding term: time the chip would
        spend on MODEL_FLOPS at peak, divided by the dominant-term time."""
        useful_s = self.model_flops / self.n_chips / PEAK_FLOPS
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        return useful_s / bound if bound > 0 else 0.0

    def to_json(self) -> Dict[str, Any]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def derive_terms(cost: Dict[str, float], coll: CollectiveStats,
                 mflops: float, n_chips: int) -> RooflineTerms:
    """cost = compiled.cost_analysis() of the partitioned (per-device) module."""
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))
    per_chip_useful = mflops / n_chips
    return RooflineTerms(
        compute_s=hlo_flops / PEAK_FLOPS,
        memory_s=hlo_bytes / HBM_BW,
        collective_s=coll.bytes_total / ICI_BW,
        model_flops=mflops,
        hlo_flops=hlo_flops,
        useful_ratio=(per_chip_useful / hlo_flops) if hlo_flops else 0.0,
        n_chips=n_chips,
    )


def derive_terms_from_totals(totals, mflops: float, n_chips: int
                             ) -> RooflineTerms:
    """Terms from the trip-count-aware HLO cost model (launch.hlocost) —
    the source of record for §Roofline (cost_analysis undercounts loops)."""
    per_chip_useful = mflops / n_chips
    return RooflineTerms(
        compute_s=totals.flops / PEAK_FLOPS,
        memory_s=totals.bytes / HBM_BW,
        collective_s=totals.collective_bytes / ICI_BW,
        model_flops=mflops,
        hlo_flops=totals.flops,
        useful_ratio=(per_chip_useful / totals.flops) if totals.flops else 0.0,
        n_chips=n_chips,
    )
