"""Serving launcher: config + continuous-batching server wiring.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \\
        --requests 8 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Backbone, get_config, reduced
from repro.runtime.serve_loop import Request, Server


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    bb = Backbone(cfg, compute_dtype=jnp.float32, remat=False)
    params = bb.init(jax.random.PRNGKey(0))
    srv = Server(bb, params, slots=args.slots, ctx=args.ctx)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                        dtype=np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        srv.submit(r)
    t0 = time.monotonic()
    srv.run()
    dt = time.monotonic() - t0
    done = sum(r.done.is_set() for r in reqs)
    print(f"[serve] {cfg.name}: {done}/{len(reqs)} requests, "
          f"{srv.stats['tokens']} tokens in {dt:.2f}s "
          f"({srv.stats['tokens']/max(dt,1e-9):.0f} tok/s incl. compiles), "
          f"{srv.stats['steps']} batch steps")
    print("[serve] sample:", reqs[0].out)


if __name__ == "__main__":
    main()
