"""Trip-count-aware cost analysis of optimized HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE — for a
layer-scanned transformer that undercounts FLOPs/bytes/collectives by the
layer count. XLA *does* annotate loops with ``known_trip_count`` after
simplification, so this module re-derives costs from the partitioned HLO
text with loop bodies multiplied out:

* **FLOPs** — exact for ``dot`` ops (2 · prod(output) · prod(contracted lhs
  dims)), resolved through a per-computation SSA symbol table (post-opt HLO
  prints operand *names* only). Dots inside fusion computations are
  traversed too. Elementwise FLOPs are ignored (matmul-dominated models);
  the roofline's compute term is therefore a slight *under*-estimate, which
  is the conservative direction for a bound.
* **Bytes** — fusion-aware traffic model: each computation-level op
  contributes its operand + output bytes (a fusion reads its inputs and
  writes its output once — internals stay in registers/VMEM). Bookkeeping
  ops (parameter/constant/tuple/get-tuple-element/bitcast/while/conditional)
  are skipped.
* **Collectives** — output-shape bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, per op kind, with loop
  multipliers; ``in_loop_bytes`` tracks collectives executing with
  multiplier > 1 (the early-release-schedule signature).

The traversal is a memoized DAG walk: ENTRY ×1; ``while`` bodies ×
known_trip_count; fusions/calls/conditionals ×1.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "after-all", "iota", "opt-barrier",
    "partition-id", "replica-id",
}

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_HEADER = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->")
_TRIP = re.compile(r'known_trip_count[\\":{ ]+n[\\": ]+(\d+)')
_CALLEE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_CALLEES = re.compile(
    r"(?:true_computation|false_computation|branch_computations)=\{?%?([\w.\-, %]+)\}?")
_OPERAND_NAME = re.compile(r"%([\w.\-]+)")


def _shapes_bytes(text: str) -> int:
    return sum(_nelems(dims) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_TOKEN.findall(text))


def _nelems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _parse_dims(dims: str) -> List[int]:
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class _Op:
    name: str
    kind: str
    out_text: str               # text between '=' and op kind (output shapes)
    rest: str                   # operand list + attributes
    out_dims: List[int] = field(default_factory=list)
    out_dtype: str = "f32"


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)
    symbols: Dict[str, Tuple[str, List[int]]] = field(default_factory=dict)


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_count: float = 0.0
    coll_by_op: Dict[str, float] = field(default_factory=dict)
    coll_by_op_count: Dict[str, float] = field(default_factory=dict)
    in_loop_bytes: float = 0.0
    in_loop_count: float = 0.0
    unknown_custom_calls: List[str] = field(default_factory=list)

    def add(self, other: "CostTotals", mult: float, in_loop: bool) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.collective_bytes += mult * other.collective_bytes
        self.collective_count += mult * other.collective_count
        for k, v in other.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0.0) + mult * v
        for k, v in other.coll_by_op_count.items():
            self.coll_by_op_count[k] = (self.coll_by_op_count.get(k, 0.0)
                                        + mult * v)
        if in_loop or mult > 1:
            self.in_loop_bytes += mult * other.collective_bytes
            self.in_loop_count += mult * other.collective_count
        else:
            self.in_loop_bytes += mult * other.in_loop_bytes
            self.in_loop_count += mult * other.in_loop_count
        self.unknown_custom_calls.extend(other.unknown_custom_calls)

    def to_json(self) -> Dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "collective_count": self.collective_count,
            "coll_by_op": self.coll_by_op,
            "coll_by_op_count": self.coll_by_op_count,
            "in_loop_bytes": self.in_loop_bytes,
            "in_loop_count": self.in_loop_count,
            "unknown_custom_calls": sorted(set(self.unknown_custom_calls)),
        }


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, _Computation] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, CostTotals] = {}

    # ------------------------------------------------------------------ #
    def _parse(self, text: str) -> None:
        comp: Optional[_Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            s = line.strip()
            if not s or s.startswith("//"):
                continue
            if s.endswith("{") and "=" not in s.split("(")[0]:
                m = _COMP_HEADER.match(s)
                if m:
                    comp = _Computation(m.group(1))
                    self.comps[comp.name] = comp
                    if s.lstrip().startswith("ENTRY"):
                        self.entry = comp.name
                continue
            if s == "}":
                continue
            m = _OP_LINE.match(line)
            if m and comp is not None:
                name, out_text, kind, rest = m.groups()
                op = _Op(name=name, kind=kind, out_text=out_text, rest=rest)
                toks = _SHAPE_TOKEN.findall(out_text)
                if toks:
                    op.out_dtype, dims = toks[0]
                    op.out_dims = _parse_dims(dims)
                comp.ops.append(op)
                comp.symbols[name] = (op.out_dtype, op.out_dims)
        if self.entry is None and self.comps:
            # last computation is usually ENTRY in HLO dumps
            self.entry = list(self.comps)[-1]

    # ------------------------------------------------------------------ #
    def _dot_flops(self, comp: _Computation, op: _Op) -> float:
        out_n = _nelems_list(op.out_dims)
        lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
        if lc is None:
            return 0.0
        lhs_name_m = _OPERAND_NAME.search(op.rest)
        if lhs_name_m is None:
            return 0.0
        lhs = comp.symbols.get(lhs_name_m.group(1))
        if lhs is None:
            return 0.0
        _, lhs_dims = lhs
        contracted = 1
        for idx in (int(i) for i in lc.group(1).split(",") if i):
            if idx < len(lhs_dims):
                contracted *= lhs_dims[idx]
        return 2.0 * out_n * contracted

    _SLICE_OPS = {"dynamic-slice", "slice", "gather"}
    _TRANSPARENT = {"get-tuple-element", "bitcast", "tuple"}

    def _op_bytes(self, comp: _Computation, op: _Op) -> float:
        """Traffic for one op under the written-once/read-once model:
        every produced tensor is written to HBM and read back by its
        consumer exactly once (2 × output bytes). Reads of *computation
        parameters* — which no local op produced — are charged separately
        in ``_parameter_read_bytes``. This optimistic-reuse model is the
        right flavor for a roofline term: it bounds mandatory traffic."""
        out_bytes = float(_shapes_bytes(op.out_text))
        if op.kind in self._SLICE_OPS:
            return 2.0 * out_bytes
        if op.kind == "dynamic-update-slice":
            # in-place read-modify-write of just the update region
            names = _OPERAND_NAME.findall(op.rest.split("),")[0])
            upd = comp.symbols.get(names[1]) if len(names) > 1 else None
            if upd is not None:
                dt, dims = upd
                return 2.0 * _nelems_list(dims) * _DTYPE_BYTES.get(dt, 4)
            return out_bytes
        return 2.0 * out_bytes

    def _consumer_map(self, comp: _Computation) -> Dict[str, List[_Op]]:
        consumers: Dict[str, List[_Op]] = {}
        for op in comp.ops:
            for nm in _OPERAND_NAME.findall(op.rest.split("),")[0]):
                consumers.setdefault(nm, []).append(op)
        return consumers

    def _fusion_slices_operand(self, fusion_op: _Op, operand_pos: int) -> Optional[float]:
        """If the fused computation consumes parameter ``operand_pos`` only
        through slicing ops or as the in-place target of
        dynamic-update-slice, return the touched bytes; else None (caller
        charges the full operand). Catches both scan-sliced stacked weights
        (reads) and scan accumulators (in-place writes) — charging either
        at full stack size per iteration is the dominant overcount mode."""
        m = _CALLEE.search(fusion_op.rest)
        fused = self.comps.get(m.group(1)) if m else None
        if fused is None:
            return None
        pname = None
        for iop in fused.ops:
            if iop.kind == "parameter":
                num = iop.rest.split(")")[0].strip()
                if num.isdigit() and int(num) == operand_pos:
                    pname = iop.name
                    break
        if pname is None:
            return None
        consumers = [iop for iop in fused.ops
                     if pname in _OPERAND_NAME.findall(
                         iop.rest.split("),")[0])]
        if not consumers:
            return 0.0
        total = 0.0
        for c in consumers:
            if c.kind in self._SLICE_OPS:
                total += 2.0 * float(_shapes_bytes(c.out_text))
            elif c.kind == "dynamic-update-slice":
                names = _OPERAND_NAME.findall(c.rest.split("),")[0])
                upd = fused.symbols.get(names[1]) if len(names) > 1 else None
                if upd is not None:
                    dt, dims = upd
                    total += 2.0 * _nelems_list(dims) * _DTYPE_BYTES.get(dt, 4)
            else:
                return None  # direct full consumption
        return total

    def _parameter_read_bytes(self, comp: _Computation) -> float:
        """Charge reads of computation parameters (loop carries, weights):
        walk parameter-derived names through transparent ops; names consumed
        only by slice-family ops cost their slice sizes (already counted as
        the slice op's output), names consumed directly cost one full read.
        Fusions that internally slice a parameter count at slice size."""
        consumers = self._consumer_map(comp)
        total = 0.0
        frontier: List[str] = [op.name for op in comp.ops
                               if op.kind == "parameter"]
        seen = set(frontier)
        while frontier:
            nm = frontier.pop()
            sym = comp.symbols.get(nm)
            for c in consumers.get(nm, []):
                if c.kind in self._TRANSPARENT:
                    if c.name not in seen:
                        seen.add(c.name)
                        frontier.append(c.name)
                    continue
                if c.kind in self._SLICE_OPS or c.kind == "dynamic-update-slice":
                    continue  # slice-size charged at the slice op itself
                if c.kind == "fusion":
                    opnames = _OPERAND_NAME.findall(c.rest.split("),")[0])
                    pos = opnames.index(nm) if nm in opnames else -1
                    sliced = self._fusion_slices_operand(c, pos) if pos >= 0 else None
                    if sliced is not None:
                        total += sliced
                        continue
                # direct full read of this parameter-derived tensor
                if sym is not None:
                    dt, dims = sym
                    total += _nelems_list(dims) * _DTYPE_BYTES.get(dt, 4)
                break  # charge at most one full read per derived name
        return total

    def _local(self, comp_name: str) -> Tuple[CostTotals, List[Tuple[str, float]]]:
        comp = self.comps[comp_name]
        totals = CostTotals()
        calls: List[Tuple[str, float]] = []
        for op in comp.ops:
            kind = op.kind
            if kind == "dot":
                totals.flops += self._dot_flops(comp, op)
                totals.bytes += self._op_bytes(comp, op)
                continue
            base = kind.replace("-start", "")
            if base in COLLECTIVE_OPS:
                nbytes = float(_shapes_bytes(op.out_text))
                totals.collective_bytes += nbytes
                totals.collective_count += 1
                totals.coll_by_op[base] = totals.coll_by_op.get(base, 0.0) + nbytes
                totals.coll_by_op_count[base] = (
                    totals.coll_by_op_count.get(base, 0.0) + 1)
                totals.bytes += self._op_bytes(comp, op)
                continue
            if kind == "while":
                trip_m = _TRIP.search(op.rest)
                trip = float(trip_m.group(1)) if trip_m else 1.0
                # Loops marked vmem_kernel_* are Pallas kernels on the TPU
                # target: their chunk buffers never leave VMEM, so bytes are
                # charged as kernel I/O only (the while's carry+ys, once);
                # FLOPs and collectives still scale with the trip count.
                is_kernel = "vmem_kernel" in op.rest
                if is_kernel:
                    totals.bytes += 2.0 * float(_shapes_bytes(op.out_text))
                for callee_kind, callee in re.findall(
                        r"(body|condition)=%?([\w.\-]+)", op.rest):
                    mult = trip if callee_kind == "body" else 0.0
                    calls.append((callee, -2.0 * mult if is_kernel and mult
                                  else mult))
                continue
            if kind in ("fusion", "call", "map", "reduce", "reduce-window",
                        "scatter", "select-and-scatter", "sort"):
                m = _CALLEE.search(op.rest)
                if m and m.group(1) in self.comps:
                    # traverse for FLOPs only (dots inside fusions)
                    calls.append((m.group(1), -1.0))
                if kind not in _SKIP_BYTES_OPS:
                    totals.bytes += self._op_bytes(comp, op)
                continue
            if kind == "conditional":
                for grp in re.findall(r"%([\w.\-]+)", op.rest):
                    if grp in self.comps:
                        calls.append((grp, 1.0))
                continue
            if kind == "custom-call":
                tgt = re.search(r'custom_call_target="([^"]+)"', op.rest)
                if tgt:
                    totals.unknown_custom_calls.append(tgt.group(1))
                totals.bytes += self._op_bytes(comp, op)
                continue
            if kind in _SKIP_BYTES_OPS:
                continue
            totals.bytes += self._op_bytes(comp, op)
        # reads of loop carries / weights / arguments (parameters)
        totals.bytes += self._parameter_read_bytes(comp)
        return totals, calls

    def total(self, comp_name: Optional[str] = None) -> CostTotals:
        comp_name = comp_name or self.entry
        return self._total_rec(comp_name, set())

    def _total_rec(self, name: str, stack: frozenset) -> CostTotals:
        if name in self._memo:
            return self._memo[name]
        if name in stack or name not in self.comps:  # safety
            return CostTotals()
        local, calls = self._local(name)
        out = CostTotals()
        out.add(local, 1.0, in_loop=False)
        for callee, mult in calls:
            sub = self._total_rec(callee, stack | {name})
            if mult == -1.0:  # fusion: flops only
                fl = CostTotals(flops=sub.flops,
                                collective_bytes=sub.collective_bytes,
                                collective_count=sub.collective_count,
                                coll_by_op=dict(sub.coll_by_op),
                                coll_by_op_count=dict(sub.coll_by_op_count))
                out.add(fl, 1.0, in_loop=False)
            elif mult <= -2.0:  # vmem-kernel body: flops × trip; no bytes;
                # collectives × 1 — a Pallas kernel contains no collectives,
                # so any the GSPMD fallback placed inside are boundary
                # reshards that the kernel path hoists out of the loop.
                trip = -mult / 2.0
                fl = CostTotals(flops=sub.flops)
                out.add(fl, trip, in_loop=False)
                cl = CostTotals(collective_bytes=sub.collective_bytes,
                                collective_count=sub.collective_count,
                                coll_by_op=dict(sub.coll_by_op),
                                coll_by_op_count=dict(sub.coll_by_op_count))
                out.add(cl, 1.0, in_loop=False)
            elif mult > 0:
                out.add(sub, mult, in_loop=mult > 1)
        self._memo[name] = out
        return out


def _nelems_list(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def analyze(hlo_text: str) -> CostTotals:
    return HloCostModel(hlo_text).total()
