"""Training launcher: config + mesh + trainer wiring.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \\
        --steps 50 --batch 8 --seq 128

On a real TPU pod the same entry point runs with ``--mesh production``
(jax.distributed initializes from the TPU environment; the dry-run proves
every assigned config lowers on that mesh). On this CPU container the
default ``--mesh host`` trains reduced configs end-to-end.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import Backbone, get_config, reduced
from repro.optim import adamw
from repro.runtime.steps import StepSettings
from repro.runtime.train_loop import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced smoke config (CPU-sized)")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "production", "multipod"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--zero3", type=int, default=0)
    ap.add_argument("--remat", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    settings = StepSettings(zero3=bool(args.zero3), gather_weights=bool(args.zero3),
                            remat=bool(args.remat), moe_ep=False)
    bb = Backbone(cfg, compute_dtype=jnp.float32, remat=settings.remat)
    n = sum(x.size for x in jax.tree_util.tree_leaves(
        jax.eval_shape(bb.init, jax.random.PRNGKey(0))))
    print(f"[launch] {cfg.name}: {n/1e6:.1f}M params, "
          f"{jax.device_count()} devices")

    trainer = Trainer(
        bb,
        adamw.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                          total_steps=args.steps),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch,
                   enc_seq=cfg.enc_seq, enc_dim=cfg.d_model),
        TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, log_every=10),
        settings)
    try:
        state = trainer.init_or_restore()
        trainer.run(state)
        log = trainer.metrics_log
        print(f"[launch] done: loss {log[0]['loss']:.4f} -> "
              f"{log[-1]['loss']:.4f}; checkpoints {trainer.async_ckpt.saved}")
    finally:
        trainer.shutdown()


if __name__ == "__main__":
    main()
