"""Sharding rules: parameter specs, batch/cache specs, activation sharder.

Parameter policy (see DESIGN.md §5):

* TP ("model" axis): attention head dims, FFN hidden dim, expert dim (EP)
  when divisible, vocab dim of embeddings.
* ZeRO ("data" axis): the non-TP matrix dim of every large 2-D kernel.
  With ``zero3=True`` parameters themselves are sharded over "data" —
  the backward pass then reduce-scatters each layer's gradient *inside*
  the layer scan (the OptSVA-CF "early release on last write" schedule).
  With ``zero3=False`` parameters are replicated over "data" and the
  gradient all-reduce happens once after the backward scan ("release at
  commit", the SVA-like baseline). Both lower; §Perf compares them.
* "pod" axis: pure DP — parameters replicated, batch sharded.

Everything is name/shape-pattern based over the backbone's parameter tree,
so new layer kinds only need a rule here if they introduce new leaf names.
"""
from __future__ import annotations

import re
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.backbone import Backbone
from repro.models.config import ModelConfig, ShapeConfig
from .mesh import dp_axes, tp_size

Params = Any


def _divisible(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


def full_dp_arch(cfg: ModelConfig) -> bool:
    """Attention-free (SSM) archs get nothing from tensor parallelism but
    per-layer activation all-reduces (tiny per-layer matmuls, low arithmetic
    intensity). For them the "model" axis is repurposed as additional data
    parallelism: batch sharded over data×model, weights ZeRO-sharded over
    both and gathered per layer (the early-release prefetch) — measured 13×
    lower collective volume on rwkv6 train_4k (EXPERIMENTS.md §Perf)."""
    return cfg.family == "ssm"


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               cfg: ModelConfig, mesh: Mesh, *, zero3: bool = True,
               full_dp: bool = False) -> P:
    """PartitionSpec for one parameter leaf, by name pattern + shape."""
    spec = _param_spec_raw(path, shape, cfg, mesh, zero3=zero3)
    if full_dp:
        spec = P(*(None if s == "model" else s for s in spec))
    return spec


def _param_spec_raw(path: Tuple[str, ...], shape: Tuple[int, ...],
                    cfg: ModelConfig, mesh: Mesh, *, zero3: bool = True) -> P:
    name = path[-1]
    tp = tp_size(mesh)
    zaxis = "data" if zero3 else None

    def zshard(dim: int) -> Optional[str]:
        return zaxis if _divisible(shape[dim], mesh.shape.get("data", 1)) else None

    # ---- embeddings / head ---------------------------------------------------
    if name == "tok":                       # [Vp, D]
        if not cfg.tie_embeddings:
            # untied: vocab over "data" (ZeRO) + D over "model" — the lookup
            # all-reduce then runs on the model-sharded (16x smaller) output
            return P(zshard(0), "model")
        return P("model", zshard(1))
    if name == "enc_pos":                   # [enc_seq, D]
        return P(None, None)
    if name == "lm_head":                   # [D, Vp]
        return P(zshard(0), "model")
    if name == "final_norm":
        return P(None)

    # ---- stacked layer leaves: shape[0] is the repeat axis -------------------
    if len(shape) == 4 and name in ("w_gate", "w_up", "w_down") \
            and cfg.ffn_kind == "moe":
        # experts [R, E|V, D, Fe] / [R, E|V, Fe, D]; the EP path stores
        # virtualized experts whose dim-1 always divides tp
        if _divisible(shape[1], tp):
            return P(None, "model", zshard(2), None)
        # TP inside the expert instead (GSPMD baseline, few big experts)
        if name == "w_down":
            return P(None, None, "model", zshard(3))
        return P(None, None, zshard(2), "model")
    if name == "router":                    # [R, D, E]
        return P(None, zshard(1), None)
    if name in ("wq", "wk", "wv", "c_wq", "c_wk", "c_wv",
                "w_r", "w_k", "w_v", "w_g"):
        return P(None, zshard(1), "model")  # [R, D, out]
    if name in ("wo", "c_wo", "w_o"):
        return P(None, "model", zshard(2))  # [R, out, D]
    if name in ("w_gate", "w_up", "w_in", "w_gate_branch"):
        return P(None, zshard(1), "model")  # [R, D, F/W]
    if name in ("w_down", "w_out"):
        return P(None, "model", zshard(2))  # [R, F/W, D]
    if name == "w_rgate":                   # [R, D, D]
        return P(None, zshard(1), "model")
    if name in ("bq", "bk", "bv", "c_bq", "c_bk", "c_bv",
                "u", "w0", "ln_x", "conv_b", "gb_a", "gb_x", "a_log"):
        return P(None, "model") if _divisible(shape[1], tp) else P(None, None)
    if name == "conv_w":                    # [R, K, W]
        return P(None, None, "model")
    if name in ("gw_a", "gw_x"):            # [R, NB, wb, wb]
        return P(None, "model", None, None) if _divisible(shape[1], tp) \
            else P(None, None, None, None)
    if name in ("wd_a", "dd_a"):            # [R, D, r]
        return P(None, zshard(1), None)
    if name == "wd_b":                      # [R, r, Dr]
        return P(None, None, "model")
    if name.startswith("dd_b"):             # [R, 32, D]
        return P(None, None, zshard(2))
    # norms, mu_*, small vectors -> replicated
    return P(*([None] * len(shape)))


def param_shardings(bb: Backbone, mesh: Mesh, *, zero3: bool = True,
                    full_dp: bool = False) -> Params:
    specs = bb.param_specs()

    def to_sharding(path, leaf):
        names = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path)
        spec = param_spec(names, leaf.shape, bb.cfg, mesh, zero3=zero3,
                          full_dp=full_dp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(to_sharding, specs)


# --------------------------------------------------------------------------- #
# Batches and caches                                                           #
# --------------------------------------------------------------------------- #
def batch_spec(mesh: Mesh) -> P:
    return P(dp_axes(mesh) or None)


def full_dp_active(cfg: ModelConfig, mesh: Mesh, global_batch: int) -> bool:
    """full-DP applies only when the batch divides the whole device grid."""
    if not full_dp_arch(cfg):
        return False
    total = 1
    for a in dp_axes(mesh) + ("model",):
        total *= mesh.shape[a]
    return _divisible(global_batch, total)


def effective_dp(cfg: ModelConfig, mesh: Mesh, global_batch: int
                 ) -> Tuple[str, ...]:
    """Batch-sharding axes: data(+pod); plus 'model' for full-DP archs
    when the batch divides the larger grid."""
    dp = dp_axes(mesh)
    if full_dp_active(cfg, mesh, global_batch):
        return dp + ("model",)
    return dp


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    *, batch_sharded: bool = True) -> Dict[str, NamedSharding]:
    dp = effective_dp(cfg, mesh, shape.global_batch) if batch_sharded else ()
    tok = NamedSharding(mesh, P(dp or None, None))
    out = {"tokens": tok}
    if shape.kind == "train":
        out["labels"] = tok
    if cfg.is_enc_dec:
        out["enc_frames"] = NamedSharding(mesh, P(dp or None, None, None))
    return out


def cache_shardings(bb: Backbone, mesh: Mesh, B: int) -> Params:
    """Cache specs: batch over dp (when divisible), heads/width over model."""
    cache_shape = jax.eval_shape(lambda: bb.init_cache(B, 8))
    dp = dp_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    bshard = dp if _divisible(B, dp_total) else None
    tp = tp_size(mesh)

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shp = leaf.shape
        if name == "pos":
            return NamedSharding(mesh, P())
        if name == "kpos":
            return NamedSharding(mesh, P(None, None))
        if name in ("k", "v", "ck", "cv"):    # [R, B, C, KV, hd]
            kv = "model" if _divisible(shp[3], tp) else None
            return NamedSharding(mesh, P(None, bshard, None, kv, None))
        if name == "wkv":                     # [R, B, H, hd, hd]
            h = "model" if _divisible(shp[2], tp) else None
            return NamedSharding(mesh, P(None, bshard, h, None, None))
        if name in ("shift1", "shift2"):      # [R, B, D]
            return NamedSharding(mesh, P(None, bshard, None))
        if name == "conv":                    # [R, B, K-1, W]
            w = "model" if _divisible(shp[3], tp) else None
            return NamedSharding(mesh, P(None, bshard, None, w))
        if name == "h":                       # [R, B, W]
            w = "model" if _divisible(shp[2], tp) else None
            return NamedSharding(mesh, P(None, bshard, w))
        return NamedSharding(mesh, P(*([None] * len(shp))))

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def make_param_gatherer(cfg: ModelConfig, mesh: Mesh, *,
                        full_dp: bool = False) -> Callable:
    """Per-layer weight-gather constraint for the scan body.

    Under ZeRO-3 ("data"-sharded weights), constraining the *sliced* layer
    parameters to their TP-only sharding inside the scan body makes GSPMD
    all-gather each layer's weights right before use (prefetch — the
    paper's asynchronous read-only buffering) and reduce-scatter each
    layer's gradient right after its backward (early release on last
    write), instead of all-reducing activations at every matmul whose
    contraction dim is "data"-sharded.
    """

    def gather(layer_params: Params) -> Params:
        def one(path, leaf):
            names = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
            # rules index shapes with the stacked dim first; re-add it
            spec = param_spec(names, (1,) + leaf.shape, cfg, mesh,
                              zero3=False, full_dp=full_dp)
            sliced = P(*spec[1:]) if len(spec) > 1 else P()
            if len(sliced) != leaf.ndim:
                return leaf
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, sliced))

        return jax.tree_util.tree_map_with_path(one, layer_params)

    return gather


# --------------------------------------------------------------------------- #
# Activation sharder                                                           #
# --------------------------------------------------------------------------- #
def make_sharder(cfg: ModelConfig, mesh: Mesh,
                 *, batch_sharded: bool = True,
                 global_batch: int = 0) -> Callable:
    dp = (effective_dp(cfg, mesh, global_batch or 1 << 30)
          if batch_sharded else ())
    dps = dp or None
    tp = tp_size(mesh)
    ep = cfg.ffn_kind == "moe" and _divisible(cfg.n_experts, tp)
    fdp = batch_sharded and full_dp_active(cfg, mesh, global_batch or 1 << 30)

    rules: Dict[str, P] = {
        "act_hidden": P(dps, None, None),
        "act_heads": P(dps, None, None if fdp else "model"),
        "logits": P(dps, None, None if fdp else "model"),
        "moe_buf": P("model", None, None) if ep else P(None, None, "model"),
    }

    def shard(x: jax.Array, name: str) -> jax.Array:
        spec = rules.get(name)
        if spec is None or len(spec) != x.ndim:
            # unknown tag or rank mismatch (e.g. decode-step edge): no-op
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard
