"""runtime subpackage."""
