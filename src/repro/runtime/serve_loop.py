"""Serving runtime: synchronized batched decode with slot-based admission.

A deliberately compact continuous-batching server: a fixed number of decode
*slots* share one jitted decode step; finished sequences free their slot and
queued requests are admitted by resetting that slot's cache region (the
per-slot reset is exact because every cache entry is batch-major).

Model versions are served through the transactional store: a weight-swap
(new checkpoint) is an update transaction; in-flight decode steps finish on
the version they started with — readers never observe a torn swap.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.backbone import Backbone


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: threading.Event = field(default_factory=threading.Event)


class Server:
    def __init__(self, bb: Backbone, params, *, slots: int = 4,
                 ctx: int = 256):
        self.bb = bb
        self.params = params
        self.slots = slots
        self.ctx = ctx
        self._decode = jax.jit(bb.decode_step)
        self._prefill = jax.jit(lambda p, b: bb.prefill(p, b, ctx))
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._stop = threading.Event()
        self.stats = {"steps": 0, "tokens": 0, "admitted": 0}

    def submit(self, req: Request) -> None:
        self._queue.put(req)

    # ------------------------------------------------------------------ #
    def run(self, max_steps: int = 10_000) -> None:
        """Drive the batch loop until the queue drains (synchronous API)."""
        active: List[Optional[Request]] = [None] * self.slots
        cache = None
        next_tok = jnp.zeros((self.slots, 1), jnp.int32)

        def admit() -> bool:
            nonlocal cache, next_tok
            changed = False
            for i in range(self.slots):
                if active[i] is not None:
                    continue
                try:
                    req = self._queue.get_nowait()
                except queue.Empty:
                    break
                # per-request prefill in a batch-1 slice, then merge caches
                batch = {"tokens": jnp.asarray(req.prompt[None, :])}
                logits, c1 = self._prefill(self.params, batch)
                tok = int(jnp.argmax(logits[0, -1, :self.bb.cfg.vocab]))
                req.out.append(tok)
                if cache is None:
                    cache = self.bb.init_cache(self.slots, self.ctx)
                cache = _merge_slot(cache, c1, i)
                next_tok = next_tok.at[i, 0].set(tok)
                active[i] = req
                self.stats["admitted"] += 1
                changed = True
            return changed

        for _ in range(max_steps):
            admit()
            if all(a is None for a in active):
                if self._queue.empty():
                    return
                continue
            logits, cache = self._decode(self.params, cache, next_tok)
            self.stats["steps"] += 1
            toks = jnp.argmax(logits[:, -1, :self.bb.cfg.vocab], axis=-1)
            for i, req in enumerate(active):
                if req is None:
                    continue
                tok = int(toks[i])
                req.out.append(tok)
                self.stats["tokens"] += 1
                if len(req.out) >= req.max_new:
                    req.done.set()
                    active[i] = None
            next_tok = toks[:, None].astype(jnp.int32)


def _merge_slot(cache, one, i):
    """Copy batch-1 cache ``one`` into slot ``i`` of the batched cache."""

    def merge(dst, src):
        if dst.ndim >= 2 and src.shape[0] == dst.shape[0] and dst.ndim == src.ndim \
                and dst.shape[2:] == src.shape[2:] and src.shape[1] == 1 \
                and dst.shape[1] > 1:
            return dst.at[:, i].set(src[:, 0])
        return src  # scalars (pos) and shared leaves (kpos)

    return jax.tree_util.tree_map(merge, cache, one)
