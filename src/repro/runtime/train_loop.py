"""The training runtime: loop, fault tolerance, stragglers, elasticity.

The control plane runs on the transactional store (``repro.txstore``):

* every step commits (params, opt, cursor) as one write transaction —
  readers can never observe a torn step;
* checkpoints are taken by an irrevocable read-only transaction (snapshot
  happens asynchronously per paper §2.7) and written by a background
  thread (``AsyncCheckpointer``) — the trainer never blocks on disk;
* crash/restart resumes from the newest atomic checkpoint + the stateless
  data pipeline cursor;
* stragglers are detected by a step-time EWMA z-test; mitigation is a
  pluggable policy (on a real cluster: re-slice the batch / evict the
  slow host — here: recorded + surfaced);
* elastic rescale re-device_puts state under new shardings inside a store
  transaction, so concurrent readers see the old or the new sharding,
  never a mix.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.store import AsyncCheckpointer, CheckpointStore
from repro.data.pipeline import DataConfig, Pipeline, make_batch
from repro.models.backbone import Backbone
from repro.optim import adamw
from repro.runtime.steps import (StepSettings, init_train_state,
                                 make_train_step)
from repro.txstore.store import VersionedStateStore


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    straggler_zscore: float = 4.0
    straggler_warmup: int = 10
    keep_ckpts: int = 3


@dataclass
class StragglerStats:
    ewma: float = 0.0
    ewvar: float = 0.0
    n: int = 0
    events: List[Dict[str, float]] = field(default_factory=list)

    def observe(self, dt: float, step: int, z_thresh: float,
                warmup: int) -> bool:
        self.n += 1
        if self.n == 1:
            self.ewma = dt
            return False
        # z against the PRE-update statistics (the outlier must not be
        # allowed to widen the band it is tested against); sd floored at
        # 5% of the mean so warm, uniform phases don't fire on jitter.
        sd = max(np.sqrt(self.ewvar), 0.05 * self.ewma, 1e-9)
        z = (dt - self.ewma) / sd
        hit = self.n > warmup and z > z_thresh
        if hit:
            self.events.append({"step": step, "dt": dt, "z": float(z)})
        else:
            # stragglers are excluded from the running statistics
            alpha = 0.1
            delta = dt - self.ewma
            self.ewma += alpha * delta
            self.ewvar = (1 - alpha) * (self.ewvar + alpha * delta * delta)
        return hit


class Trainer:
    def __init__(self, bb: Backbone, opt_cfg: adamw.AdamWConfig,
                 data_cfg: DataConfig, tcfg: TrainerConfig,
                 settings: StepSettings = StepSettings(),
                 *, mesh=None, state_shardings=None,
                 straggler_hook: Optional[Callable[[Dict], None]] = None):
        self.bb = bb
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.tcfg = tcfg
        self.settings = settings
        self.mesh = mesh
        self.state_shardings = state_shardings
        self.straggler_hook = straggler_hook

        self.store = VersionedStateStore()
        self.ckpt = CheckpointStore(tcfg.ckpt_dir)
        self.async_ckpt = AsyncCheckpointer(
            self.ckpt, on_done=self._on_ckpt_done)
        self.straggler = StragglerStats()
        self.metrics_log: List[Dict[str, float]] = []

        step_fn = make_train_step(bb, opt_cfg, settings)
        if mesh is not None and state_shardings is not None:
            self._step = jax.jit(step_fn, in_shardings=(state_shardings, None),
                                 donate_argnums=(0,))
        else:
            self._step = jax.jit(step_fn, donate_argnums=(0,))

    # ------------------------------------------------------------------ #
    def _on_ckpt_done(self, step: int, path: str) -> None:
        self.store.record_checkpoint(step, path)
        self.ckpt.gc(self.tcfg.keep_ckpts)

    def init_or_restore(self, seed: int = 0) -> Dict[str, Any]:
        """Fresh init, or resume from the newest checkpoint (crash restart)."""
        latest = self.ckpt.latest_step()
        template = jax.eval_shape(
            lambda k: init_train_state(self.bb, k, self.settings),
            jax.random.PRNGKey(seed))
        if latest is not None:
            zeros = jax.tree_util.tree_map(
                lambda s: np.zeros(s.shape, s.dtype), template)
            state, step = self.ckpt.restore(zeros, latest,
                                            shardings=self.state_shardings)
            self.start_step = step
            print(f"[trainer] resumed from checkpoint step {step}")
        else:
            state = init_train_state(self.bb, jax.random.PRNGKey(seed),
                                     self.settings)
            self.start_step = 0
        self.store.commit_step(None, None, self.start_step)  # cursor only
        return state

    # ------------------------------------------------------------------ #
    def run(self, state: Dict[str, Any], *, crash_at: Optional[int] = None
            ) -> Dict[str, Any]:
        pipe = Pipeline(self.data_cfg, start_step=self.start_step)
        for step in range(self.start_step, self.tcfg.total_steps):
            batch = next(pipe)
            t0 = time.monotonic()
            if crash_at is not None and step == crash_at:
                raise RuntimeError(f"injected crash at step {step}")
            state, metrics = self._step(state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            if self.straggler.observe(step=step, dt=dt,
                                      z_thresh=self.tcfg.straggler_zscore,
                                      warmup=self.tcfg.straggler_warmup):
                ev = self.straggler.events[-1]
                print(f"[straggler] step {step}: {dt*1e3:.1f}ms "
                      f"(z={ev['z']:.1f}) — mitigation hook invoked")
                if self.straggler_hook:
                    self.straggler_hook(ev)
            self.metrics_log.append({"step": step, "loss": loss, "dt": dt})
            # control-plane commit: one write txn over (params, opt, cursor)
            self.store.commit_step(state["params"], state["opt"], step + 1)
            if (step + 1) % self.tcfg.ckpt_every == 0:
                # irrevocable read-only txn -> consistent async snapshot;
                # materialize to host NOW (the copy-buffer copy): the live
                # device buffers are donated into the next step
                snap = self.store.snapshot(("params", "opt", "data_cursor"))
                host = jax.device_get({"params": snap["params"],
                                       "opt": snap["opt"]})
                self.async_ckpt.submit(host, snap["data_cursor"])
            if (step + 1) % self.tcfg.log_every == 0:
                print(f"[train] step {step+1}: loss={loss:.4f} "
                      f"({dt*1e3:.0f}ms/step)")
        self.async_ckpt.drain()
        return state

    def shutdown(self) -> None:
        self.async_ckpt.stop()
        self.store.shutdown()


# --------------------------------------------------------------------------- #
# Elastic rescale                                                              #
# --------------------------------------------------------------------------- #
def rescale_state(state: Any, new_shardings: Any) -> Any:
    """Re-place every leaf under the new mesh's shardings (elastic event).

    On a real cluster this runs after re-forming the mesh with the surviving
    hosts; the transactional store serializes it against readers so nobody
    observes a half-resharded tree.
    """
    return jax.tree_util.tree_map(
        lambda a, s: jax.device_put(a, s), state, new_shardings)
