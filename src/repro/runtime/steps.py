"""Step functions: train_step / prefill_step / decode_step builders.

These are the functions the dry-run lowers with ``.lower().compile()`` for
every (architecture × shape × mesh) cell, and the train loop executes for
the end-to-end example.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.backbone import Backbone
from repro.optim import adamw

Params = Any


@dataclass(frozen=True)
class StepSettings:
    """Schedule/memory knobs — the §Perf hillclimb levers."""

    zero3: bool = True          # ZeRO-3 "data"-sharded parameters
    gather_weights: bool = True  # per-layer weight all-gather in the scan body
    remat: bool = True
    remat_policy: str = "full"  # full | dots (save matmul outputs)
    compress_grads: bool = False
    moe_ep: bool = True         # expert-parallel MoE via shard_map (§Perf)
    microbatches: int = 1       # gradient accumulation: divides the saved-
    # activation peak by k at the cost of k sequential sub-steps


def make_train_step(bb: Backbone, opt_cfg: adamw.AdamWConfig,
                    settings: StepSettings = StepSettings()
                    ) -> Callable:
    """(state, batch) -> (state, metrics); state = {params, opt, error?}."""

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        k = settings.microbatches
        if k > 1:
            # gradient accumulation: scan over k microbatch slices; the
            # backward's saved-activation stack shrinks by k (the lever
            # that keeps big-batch cells inside HBM at scale)
            def slice_mb(i, a):
                mb = a.shape[0] // k
                return jax.lax.dynamic_slice_in_dim(a, i * mb, mb, axis=0)

            def mb_body(carry, i):
                acc, loss_acc = carry
                mb = jax.tree_util.tree_map(lambda a: slice_mb(i, a), batch)
                l, g = jax.value_and_grad(lambda p: bb.loss_fn(p, mb))(
                    state["params"])
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return (acc, loss_acc + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (grads, loss), _ = jax.lax.scan(
                mb_body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(k))
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            loss = loss / k
        else:
            def loss_of(p):
                return bb.loss_fn(p, batch)

            loss, grads = jax.value_and_grad(loss_of)(state["params"])
        if settings.compress_grads:
            grads, err = adamw.compress_with_feedback(grads, state["error"])
        new_params, new_opt, metrics = adamw.apply_updates(
            opt_cfg, state["params"], state["opt"], grads)
        new_state = {"params": new_params, "opt": new_opt}
        if settings.compress_grads:
            new_state["error"] = err
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step


def init_train_state(bb: Backbone, key: jax.Array,
                     settings: StepSettings = StepSettings()) -> Dict[str, Any]:
    params = bb.init(key)
    state = {"params": params, "opt": adamw.init_state(params)}
    if settings.compress_grads:
        state["error"] = jax.tree_util.tree_map(
            lambda a: jnp.zeros(a.shape, a.dtype), params)
    return state


def train_state_specs(bb: Backbone,
                      settings: StepSettings = StepSettings()) -> Any:
    return jax.eval_shape(lambda k: init_train_state(bb, k, settings),
                          jax.random.PRNGKey(0))


def make_prefill_step(bb: Backbone, ctx: int) -> Callable:
    def prefill_step(params, batch):
        return bb.prefill(params, batch, ctx)

    return prefill_step


def make_decode_step(bb: Backbone) -> Callable:
    def decode_step(params, cache, tokens):
        return bb.decode_step(params, cache, tokens)

    return decode_step
