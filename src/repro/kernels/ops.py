"""Jitted dispatch wrappers for the Pallas kernels.

Selection policy (``impl`` argument, default ``"auto"``):

* ``"auto"``    — Pallas on TPU backends; the pure-jnp reference path
  elsewhere (this CPU container lowers/compiles the jnp path; the Pallas
  path is exercised in tests via ``interpret=True``).
* ``"pallas"``  — force the kernel (uses interpret mode off-TPU).
* ``"ref"``     — force the jnp oracle.

The models only ever import these wrappers, so swapping the execution
substrate never touches model code.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref as kref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        forced = os.environ.get("REPRO_KERNEL_IMPL")
        if forced:
            return forced
        return "pallas" if _on_tpu() else "ref"
    return impl


# --------------------------------------------------------------------------- #
# Flash attention                                                              #
# --------------------------------------------------------------------------- #
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    logit_cap: Optional[float] = None,
                    q_offset: int = 0, impl: str = "auto",
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    impl = _resolve(impl)
    if impl == "pallas":
        from .flash_attention import flash_attention_pallas
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, logit_cap=logit_cap,
            q_offset=q_offset, block_q=block_q, block_k=block_k,
            interpret=not _on_tpu())
    # jnp fallback: the chunked online-softmax implementation from models
    from repro.models.attention import flash_attention_jnp
    q_positions = q_offset + jnp.arange(q.shape[1], dtype=jnp.int32)
    return flash_attention_jnp(q, k, v, causal=causal, window=window,
                               logit_cap=logit_cap, q_positions=q_positions)


# --------------------------------------------------------------------------- #
# RWKV-6 WKV scan                                                              #
# --------------------------------------------------------------------------- #
def rwkv6_scan(r, k, v, w, u, state, *, impl: str = "auto",
               block_t: int = 64) -> Tuple[jax.Array, jax.Array]:
    impl = _resolve(impl)
    if impl == "pallas":
        from .rwkv6_kernel import rwkv6_scan_pallas
        return rwkv6_scan_pallas(r, k, v, w, u, state, block_t=block_t,
                                 interpret=not _on_tpu())
    return kref.rwkv6_scan_ref(r, k, v, w, u, state)


# --------------------------------------------------------------------------- #
# RG-LRU scan                                                                  #
# --------------------------------------------------------------------------- #
def rglru_scan(x, a_log, gate_r, gate_i, h0, *, impl: str = "auto",
               block_t: int = 128, block_w: int = 512
               ) -> Tuple[jax.Array, jax.Array]:
    impl = _resolve(impl)
    if impl == "pallas":
        from .rglru_kernel import rglru_scan_pallas
        return rglru_scan_pallas(x, a_log, gate_r, gate_i, h0,
                                 block_t=block_t, block_w=block_w,
                                 interpret=not _on_tpu())
    return kref.rglru_scan_ref(x, a_log, gate_r, gate_i, h0)
