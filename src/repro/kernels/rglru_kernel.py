"""RG-LRU Pallas kernel (TPU target).

Grid ``(B, nw, nt)``: the hidden width is tiled into lane-aligned blocks of
``block_w`` channels (the recurrence is channel-diagonal, so width blocks
are independent and parallel); time is innermost/sequential with the
per-(batch, width-block) state vector held in VMEM scratch. Each step is
pure VPU elementwise work on a ``[block_w]`` vector.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

RGLRU_C = 8.0


def _rglru_kernel(x_ref, alog_ref, r_ref, i_ref, h0_ref, y_ref, hT_ref,
                  h_s, *, block_t: int, nt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _load():
        h_s[...] = h0_ref[0].astype(jnp.float32)

    decay = jax.nn.softplus(alog_ref[...].astype(jnp.float32))  # [block_w]

    def step(t, _):
        xt = x_ref[0, t, :].astype(jnp.float32)
        rt = r_ref[0, t, :].astype(jnp.float32)
        it = i_ref[0, t, :].astype(jnp.float32)
        a = jnp.exp(-RGLRU_C * decay * rt)
        h = a * h_s[...] + jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * (it * xt)
        h_s[...] = h
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, block_t, step, 0)

    @pl.when(ti == nt - 1)
    def _store():
        hT_ref[0] = h_s[...]


def rglru_scan_pallas(x: jax.Array, a_log: jax.Array, gate_r: jax.Array,
                      gate_i: jax.Array, h0: jax.Array, *,
                      block_t: int = 128, block_w: int = 512,
                      interpret: bool = False
                      ) -> Tuple[jax.Array, jax.Array]:
    """x, gate_r, gate_i: [B,T,W]; a_log: [W]; h0: [B,W] -> (y fp32 [B,T,W], hT)."""
    B, T, W = x.shape
    block_t = min(block_t, T)
    block_w = min(block_w, W)
    pad_t = (-T) % block_t
    pad_w = (-W) % block_w
    if pad_t or pad_w:
        pt = ((0, 0), (0, pad_t), (0, pad_w))
        x = jnp.pad(x, pt)
        gate_r = jnp.pad(gate_r, pt)
        gate_i = jnp.pad(gate_i, pt)
        a_log = jnp.pad(a_log, (0, pad_w))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    Tp, Wp = T + pad_t, W + pad_w
    nt, nw = Tp // block_t, Wp // block_w

    kernel = functools.partial(_rglru_kernel, block_t=block_t, nt=nt)
    seq_map = lambda b, wi, ti: (b, ti, wi)
    w_map = lambda b, wi, ti: (wi,)
    h_map = lambda b, wi, ti: (b, wi)

    y, hT = pl.pallas_call(
        kernel,
        grid=(B, nw, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_w), seq_map),   # x
            pl.BlockSpec((block_w,), w_map),                # a_log
            pl.BlockSpec((1, block_t, block_w), seq_map),   # gate_r
            pl.BlockSpec((1, block_t, block_w), seq_map),   # gate_i
            pl.BlockSpec((1, block_w), h_map),              # h0
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_w), seq_map),   # y
            pl.BlockSpec((1, block_w), h_map),              # hT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tp, Wp), jnp.float32),
            jax.ShapeDtypeStruct((B, Wp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(x, a_log, gate_r, gate_i, h0)
    return y[:, :T, :W], hT[:, :W]
