"""RWKV-6 WKV recurrence Pallas kernel (TPU target).

The WKV state ``S ∈ R^{hd×hd}`` per (batch, head) stays resident in VMEM
scratch across time chunks: grid ``(B·H, nt)`` with the time dimension
innermost/sequential. Each grid step streams one ``[block_t, hd]`` tile of
r/k/v/w from HBM into VMEM and walks it with a ``fori_loop`` of rank-1
updates (VPU work — the recurrence is elementwise/outer-product shaped, so
the MXU has nothing to chew on; the chunked matmul reformulation is the
documented follow-up optimization in EXPERIMENTS.md §Perf).

The initial state is read once at ``ti == 0`` and the final state written
at ``ti == nt-1``, so checkpointed decode (long_500k) round-trips state
exactly.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                state_s, *, block_t: int, nt: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _load_state():
        state_s[...] = s0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                    # [hd]

    def step(t, _):
        rt = r_ref[0, t, :].astype(jnp.float32)         # [hd]
        kt = k_ref[0, t, :].astype(jnp.float32)
        vt = v_ref[0, t, :].astype(jnp.float32)
        wt = w_ref[0, t, :].astype(jnp.float32)
        s = state_s[...]                                # [hd, hd] (k-major)
        kv = kt[:, None] * vt[None, :]                  # outer product
        y = jnp.sum((s + u[:, None] * kv) * rt[:, None], axis=0)
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        state_s[...] = wt[:, None] * s + kv
        return 0

    jax.lax.fori_loop(0, block_t, step, 0)

    @pl.when(ti == nt - 1)
    def _store_state():
        sT_ref[0] = state_s[...]


def rwkv6_scan_pallas(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                      u: jax.Array, state: jax.Array, *,
                      block_t: int = 64,
                      interpret: bool = False
                      ) -> Tuple[jax.Array, jax.Array]:
    """r,k,v,w: [B,T,H,hd]; u: [H,hd]; state: [B,H,hd,hd] -> (y fp32, state fp32)."""
    B, T, H, hd = r.shape
    block_t = min(block_t, T)
    pad_t = (-T) % block_t
    fold = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    rf, kf, vf, wf = map(fold, (r, k, v, w))
    if pad_t:
        # pad with w=1, k=0: state is untouched by padded steps
        zpad = ((0, 0), (0, pad_t), (0, 0))
        rf, kf, vf = (jnp.pad(a, zpad) for a in (rf, kf, vf))
        wf = jnp.pad(wf, zpad, constant_values=1.0)
    Tp = T + pad_t
    nt = Tp // block_t
    uf = jnp.tile(u, (B, 1))                            # [B*H, hd]
    sf = state.reshape(B * H, hd, hd)

    kernel = functools.partial(_wkv_kernel, block_t=block_t, nt=nt)
    seq_map = lambda bh, ti: (bh, ti, 0)
    bh_map = lambda bh, ti: (bh, 0)
    st_map = lambda bh, ti: (bh, 0, 0)

    y, sT = pl.pallas_call(
        kernel,
        grid=(B * H, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, hd), seq_map),    # r
            pl.BlockSpec((1, block_t, hd), seq_map),    # k
            pl.BlockSpec((1, block_t, hd), seq_map),    # v
            pl.BlockSpec((1, block_t, hd), seq_map),    # w
            pl.BlockSpec((1, hd), bh_map),              # u
            pl.BlockSpec((1, hd, hd), st_map),          # s0
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, hd), seq_map),    # y
            pl.BlockSpec((1, hd, hd), st_map),          # final state
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Tp, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf, sf)

    y = y[:, :T].reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    return y, sT.reshape(B, H, hd, hd)
