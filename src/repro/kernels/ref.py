"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantics of record: kernels are validated against these
functions with ``assert_allclose`` over shape/dtype sweeps in
``tests/test_kernels.py``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

MASK_VALUE = -1e30
RGLRU_C = 8.0


# --------------------------------------------------------------------------- #
# Flash attention oracle                                                       #
# --------------------------------------------------------------------------- #
def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        logit_cap: Optional[float] = None,
                        q_offset: int = 0) -> jax.Array:
    """Full-materialization attention. q: [B,Sq,H,hd]; k,v: [B,Skv,Hkv,hd]."""
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(Skv)
    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)
    dpos = q_pos[:, None] - kv_pos[None, :]
    valid = jnp.ones((Sq, Skv), bool)
    if causal:
        valid &= dpos >= 0
    if window is not None:
        valid &= dpos < window
    s = jnp.where(valid[None, None, None], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


# --------------------------------------------------------------------------- #
# RWKV-6 WKV recurrence oracle                                                 #
# --------------------------------------------------------------------------- #
def rwkv6_scan_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                   u: jax.Array, state: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    """Sequential WKV. r,k,v,w: [B,T,H,hd]; u: [H,hd]; state: [B,H,hd,hd].

    y_t = (S + (u⊙k_t) v_tᵀ)ᵀ r_t ;  S ← diag(w_t) S + k_t v_tᵀ
    Returns (y [B,T,H,hd] fp32, final state fp32).
    """
    r, k, v, w = (a.astype(jnp.float32) for a in (r, k, v, w))
    u = u.astype(jnp.float32)
    state = state.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp                       # [B,H,hd]
        kv = kt[..., :, None] * vt[..., None, :]   # [B,H,hd,hd]
        y = jnp.einsum("bhkv,bhk->bhv", s + u[..., :, None] * kv, rt)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    # vmem_kernel scope: this scan is the Pallas rwkv6 kernel on TPU
    with jax.named_scope("vmem_kernel_rwkv6"):
        state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state


# --------------------------------------------------------------------------- #
# RG-LRU oracle                                                                #
# --------------------------------------------------------------------------- #
def rglru_scan_ref(x: jax.Array, a_log: jax.Array, gate_r: jax.Array,
                   gate_i: jax.Array, h0: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    """Sequential RG-LRU. x, gate_r, gate_i: [B,T,W]; a_log: [W]; h0: [B,W].

    a_t = exp(-c·softplus(Λ)·r_t);  h_t = a_t h + sqrt(1-a_t²)(i_t ⊙ x_t)
    Returns (h sequence [B,T,W] fp32, final h fp32).
    """
    x = x.astype(jnp.float32)
    decay = jax.nn.softplus(a_log.astype(jnp.float32))

    def step(h, inp):
        xt, rt, it = inp
        a = jnp.exp(-RGLRU_C * decay * rt)
        h = a * h + jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 0.0)) * (it * xt)
        return h, h

    xs = (x.transpose(1, 0, 2),
          gate_r.astype(jnp.float32).transpose(1, 0, 2),
          gate_i.astype(jnp.float32).transpose(1, 0, 2))
    # vmem_kernel scope: this scan is the Pallas rglru kernel on TPU
    with jax.named_scope("vmem_kernel_rglru"):
        h, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2), h
