"""Pallas TPU kernels for the perf-critical compute paths.

Each kernel ships as <name>_kernel.py / flash_attention.py (pl.pallas_call +
BlockSpec), with ops.py jitted wrappers and ref.py pure-jnp oracles.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
