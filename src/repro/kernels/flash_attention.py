"""Flash attention forward Pallas kernel (TPU target).

Online-softmax attention tiled for VMEM: grid ``(B·Hq, nq, nk)`` with the
kv dimension innermost so the running (m, l, acc) scratch — which lives in
VMEM — persists across kv blocks of one query block. Heads are folded into
the grid's batch dimension; GQA is handled in the kv ``index_map`` (query
head ``h`` reads kv head ``h // group``), so KV is never materialized
repeated. Block shapes are multiples of the (8, 128) TPU tile; the MXU sees
``[block_q, hd] × [hd, block_k]`` and ``[block_q, block_k] × [block_k, hd]``
matmuls with fp32 accumulation via ``preferred_element_type``.

Causal/window masking and gemma-style logit soft-capping happen on the
fp32 scores inside the kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MASK_VALUE = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                block_q: int, block_k: int, nk: int, causal: bool,
                window: Optional[int], logit_cap: Optional[float],
                q_offset: int, sm_scale: float, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, -jnp.inf)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32)                      # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                      # [bk, hd]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    valid = k_pos < kv_len
    dpos = q_pos - k_pos
    if causal:
        valid &= dpos >= 0
    if window is not None:
        valid &= dpos < window
    s = jnp.where(valid, s, MASK_VALUE)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None]) * valid.astype(jnp.float32)
    alpha = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_new))
    l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=-1)
    acc_s[...] = acc_s[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_s[...] /
                    jnp.maximum(l_s[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           logit_cap: Optional[float] = None,
                           q_offset: int = 0,
                           block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: [B,Sq,Hq,hd]; k,v: [B,Skv,Hkv,hd] -> [B,Sq,Hq,hd]."""
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    group = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)

    # Fold heads into the batch/grid dimension.
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, hd)

    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_k
    nq, nk = Sq_p // block_q, Skv_p // block_k

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        # GQA: query head bh % Hq maps to kv head (bh % Hq) // group
        b = bh // Hq
        h = bh % Hq
        return (b * Hkv + h // group, ki, 0)

    kernel = functools.partial(
        _fwd_kernel, block_q=block_q, block_k=block_k, nk=nk, causal=causal,
        window=window, logit_cap=logit_cap, q_offset=q_offset,
        sm_scale=1.0 / (hd ** 0.5), kv_len=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max m
            pltpu.VMEM((block_q,), jnp.float32),       # running sum l
            pltpu.VMEM((block_q, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :Sq].reshape(B, Hq, Sq, hd).transpose(0, 2, 1, 3)
    return out
