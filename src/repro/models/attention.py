"""Attention: GQA with RoPE, windows, soft-capping; flash-style jnp fallback.

The training/prefill path is a two-level-chunked online-softmax attention
(``flash_attention_jnp``) — the same algorithm as the Pallas kernel in
``repro.kernels.flash_attention`` but expressed with ``lax.scan`` so that it
lowers on any backend with O(chunk) memory. The Pallas kernel is selected on
TPU via ``repro.kernels.ops.flash_attention`` (validated against this
implementation's oracle in tests).

GQA is computed in grouped form (queries reshaped to [B,S,n_kv,G,hd]) so KV
heads are never materialized repeated.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import apply_rope, softcap

MASK_VALUE = -1e30


def _chunk_attn_block(q, k, v, q_pos, kv_pos, *, causal: bool,
                      window: Optional[int], logit_cap: Optional[float],
                      carry=None):
    """One (q-chunk × kv-chunk) online-softmax block.

    q: [B, Cq, Hkv, G, hd]; k/v: [B, Ck, Hkv, hd];
    q_pos: [Cq]; kv_pos: [Ck]. carry = (m, l, acc) running stats.
    Returns the updated carry.
    """
    B, Cq, Hkv, G, hd = q.shape
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if logit_cap is not None:
        s = softcap(s, logit_cap)
    dpos = q_pos[:, None] - kv_pos[None, :]  # [Cq, Ck]
    valid = kv_pos[None, :] >= 0
    if causal:
        valid &= dpos >= 0
    if window is not None:
        valid &= dpos < window
    s = jnp.where(valid[None, None, None, :, :], s, MASK_VALUE)
    m_new = jnp.maximum(carry[0], jnp.max(s, axis=-1))        # [B,Hkv,G,Cq]
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(carry[0] - m_new)
    l_new = carry[1] * alpha + jnp.sum(p, axis=-1)
    acc = carry[2] * alpha[..., None] + jnp.einsum(
        "bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return (m_new, l_new, acc)


def flash_attention_jnp(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        logit_cap: Optional[float] = None,
                        q_positions: Optional[jax.Array] = None,
                        kv_positions: Optional[jax.Array] = None,
                        q_chunk: int = 512,
                        kv_chunk: int = 1024) -> jax.Array:
    """Online-softmax chunked attention with a flash custom VJP.

    q: [B, Sq, Hq, hd]; k, v: [B, Skv, Hkv, hd]. Positions default to
    aligned causal layouts; pass explicit positions for decode/ring caches
    (kv position ``-1`` marks an empty slot).
    Returns [B, Sq, Hq, hd] in q.dtype.

    The backward pass recomputes score blocks chunk-by-chunk (the flash
    backward algorithm) instead of letting autodiff stack per-chunk
    residuals across the scan — on TPU both directions are Pallas kernels
    whose block buffers never leave VMEM.
    """
    if q_positions is None:
        q_positions = jnp.arange(q.shape[1], dtype=jnp.int32)
    if kv_positions is None:
        kv_positions = jnp.arange(k.shape[1], dtype=jnp.int32)
    return _flash(q, k, v, q_positions, kv_positions, causal, window,
                  logit_cap, q_chunk, kv_chunk)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def _flash(q, k, v, q_positions, kv_positions, causal, window, logit_cap,
           q_chunk, kv_chunk):
    out, _ = _flash_fwd_impl(q, k, v, q_positions, kv_positions, causal,
                             window, logit_cap, q_chunk, kv_chunk)
    return out


def _flash_vjp_fwd(q, k, v, q_positions, kv_positions, causal, window,
                   logit_cap, q_chunk, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, q_positions, kv_positions, causal,
                               window, logit_cap, q_chunk, kv_chunk)
    return out, (q, k, v, q_positions, kv_positions, out, lse)


def _flash_vjp_bwd(causal, window, logit_cap, q_chunk, kv_chunk, res, dout):
    q, k, v, q_positions, kv_positions, out, lse = res
    dq, dk, dv = _flash_bwd_impl(q, k, v, q_positions, kv_positions, out,
                                 lse, dout, causal, window, logit_cap,
                                 q_chunk, kv_chunk)
    f0 = lambda a: jnp.zeros(a.shape, jax.dtypes.float0)
    return dq, dk, dv, f0(q_positions), f0(kv_positions)


def _flash_fwd_impl(q, k, v, q_positions, kv_positions, causal, window,
                    logit_cap, q_chunk, kv_chunk):
    """Returns (out [B,Sq,Hq,hd], lse [B,Hkv,G,Sq] fp32)."""
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    # pad to multiples
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    pad_q = nq * q_chunk - Sq
    pad_k = nk * kv_chunk - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad_q), constant_values=0)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad_k), constant_values=-1)

    qg = q.reshape(B, nq, q_chunk, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    qp = q_positions.reshape(nq, q_chunk)
    kg = k.reshape(B, nk, kv_chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vg = v.reshape(B, nk, kv_chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    kp = kv_positions.reshape(nk, kv_chunk)

    def q_body(_, q_in):
        qc, qpos = q_in

        def kv_body(carry, kv_in):
            kc, vc, kpos = kv_in
            return _chunk_attn_block(qc, kc, vc, qpos, kpos, causal=causal,
                                     window=window, logit_cap=logit_cap,
                                     carry=carry), None

        m0 = jnp.full((B, Hkv, G, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (kg, vg, kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]          # [B,Hkv,G,Cq,hd]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))              # [B,Hkv,G,Cq]
        return None, (out.transpose(0, 3, 1, 2, 4), lse)

    # vmem_kernel scope: on TPU this whole loop nest is one Pallas kernel
    # (repro.kernels.flash_attention) whose chunk buffers never leave VMEM;
    # the HLO cost model charges bytes for kernel I/O only (see hlocost).
    with jax.named_scope("vmem_kernel_flash"):
        _, (outs, lses) = jax.lax.scan(q_body, None, (qg, qp))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_chunk, Hq, hd)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, Hkv, G, nq * q_chunk)
    return out[:, :Sq].astype(q.dtype), lse[..., :Sq]


def _flash_bwd_impl(q, k, v, q_positions, kv_positions, out, lse, dout,
                    causal, window, logit_cap, q_chunk, kv_chunk):
    """Flash backward: per-block score recomputation, no stacked residuals.

    Outer scan over kv chunks carrying the dq accumulator; inner scan over
    q chunks emitting (dk, dv) per kv chunk. All fp32 accumulation.
    """
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / (hd ** 0.5)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    pad_q = nq * q_chunk - Sq
    pad_k = nk * kv_chunk - Skv

    def padq(a, fill=0):
        return jnp.pad(a, ((0, 0), (0, pad_q)) + ((0, 0),) * (a.ndim - 2),
                       constant_values=fill) if pad_q else a

    qp = jnp.pad(q_positions, (0, pad_q), constant_values=-(10 ** 9)) \
        if pad_q else q_positions
    kp = jnp.pad(kv_positions, (0, pad_k), constant_values=-1) \
        if pad_k else kv_positions
    qf = padq(q)
    outf = padq(out)
    doutf = padq(dout)
    lsef = jnp.pad(lse, ((0, 0),) * 3 + ((0, pad_q),)) if pad_q else lse
    kf = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vf = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v

    Sqp, Skvp = Sq + pad_q, Skv + pad_k
    # delta_i = rowsum(dout * out)  [B, Hkv, G, Sqp]
    delta = jnp.einsum(
        "bshd,bshd->bhs",
        doutf.astype(jnp.float32), outf.astype(jnp.float32)
    ).reshape(B, Hkv, G, Sqp)

    qg = qf.reshape(B, nq, q_chunk, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    dog = doutf.reshape(B, nq, q_chunk, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    lseg = lsef.reshape(B, Hkv, G, nq, q_chunk).transpose(3, 0, 1, 2, 4)
    dg = delta.reshape(B, Hkv, G, nq, q_chunk).transpose(3, 0, 1, 2, 4)
    qpg = qp.reshape(nq, q_chunk)
    kg = kf.reshape(B, nk, kv_chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vg = vf.reshape(B, nk, kv_chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    kpg = kp.reshape(nk, kv_chunk)

    def block_grads(qc, doc, lsec, dc, qpos, kc, vc, kpos):
        """One (q-chunk, kv-chunk) block; returns (dq_c, dk_c, dv_c)."""
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qc.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale
        if logit_cap is not None:
            t = jnp.tanh(s / logit_cap)
            u_grad = 1.0 - jnp.square(t)          # ds/du
            s = logit_cap * t
        dpos = qpos[:, None] - kpos[None, :]
        valid = kpos[None, :] >= 0
        if causal:
            valid &= dpos >= 0
        if window is not None:
            valid &= dpos < window
        p = jnp.where(valid[None, None, None],
                      jnp.exp(s - lsec[..., None]), 0.0)     # [B,h,g,q,k]
        dv_c = jnp.einsum("bhgqk,bqhgd->bkhd", p, doc.astype(jnp.float32))
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", doc.astype(jnp.float32),
                        vc.astype(jnp.float32))
        ds = p * (dp - dc[..., None])
        if logit_cap is not None:
            ds = ds * u_grad
        ds = ds * scale
        dq_c = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kc.astype(jnp.float32))
        dk_c = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qc.astype(jnp.float32))
        return dq_c, dk_c, dv_c

    def kv_body(dq_acc, kv_in):
        kc, vc, kpos = kv_in

        def q_body(carry, q_in):
            dk_a, dv_a = carry
            qc, doc, lsec, dc, qpos = q_in
            dq_c, dk_c, dv_c = block_grads(qc, doc, lsec, dc, qpos,
                                           kc, vc, kpos)
            return (dk_a + dk_c, dv_a + dv_c), dq_c

        dk0 = jnp.zeros((B, kv_chunk, Hkv, hd), jnp.float32)
        (dk_j, dv_j), dq_chunks = jax.lax.scan(
            q_body, (dk0, dk0), (qg, dog, lseg, dg, qpg))
        return dq_acc + dq_chunks, (dk_j, dv_j)

    dq0 = jnp.zeros((nq, B, q_chunk, Hkv, G, hd), jnp.float32)
    with jax.named_scope("vmem_kernel_flash_bwd"):
        dq, (dk, dv) = jax.lax.scan(kv_body, dq0, (kg, vg, kpg))
    dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sqp, Hq, hd)[:, :Sq]
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, Skvp, Hkv, hd)[:, :Skv]
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, Skvp, Hkv, hd)[:, :Skv]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def attention_reference(q, k, v, *, causal=True, window=None, logit_cap=None,
                        q_positions=None, kv_positions=None) -> jax.Array:
    """Unchunked oracle for tests (materializes full scores)."""
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    if q_positions is None:
        q_positions = jnp.arange(Sq, dtype=jnp.int32)
    if kv_positions is None:
        kv_positions = jnp.arange(Skv, dtype=jnp.int32)
    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if logit_cap is not None:
        s = softcap(s, logit_cap)
    dpos = q_positions[:, None] - kv_positions[None, :]
    valid = kv_positions[None, :] >= 0
    if causal:
        valid &= dpos >= 0
    if window is not None:
        valid &= dpos < window
    s = jnp.where(valid[None, None, None], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)
