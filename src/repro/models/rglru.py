"""RG-LRU recurrent blocks (Griffin / RecurrentGemma).

The recurrence (per channel, gates block-diagonal over heads):

    r_t = σ(W_a x_t + b_a)          recurrence gate
    i_t = σ(W_x x_t + b_x)          input gate
    a_t = exp(-c · softplus(Λ) · r_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

wrapped in the Griffin recurrent block: two input branches (recurrent branch
with a short causal conv1d; gate branch with GELU), elementwise merge, output
projection. The scan runs through ``repro.kernels.ops.rglru_scan``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

_C = 8.0  # Griffin's fixed decay temperature


def causal_conv1d(params: Dict, x: jax.Array, conv_state: jax.Array,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: [B,T,W]; conv_state: [B,K-1,W]."""
    w = params["conv_w"]                       # [K, W]
    K = w.shape[0]
    xin = jnp.concatenate([conv_state, x], axis=1)   # [B, T+K-1, W]
    out = sum(xin[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    out = out + params["conv_b"]
    new_state = xin[:, -(K - 1):, :] if K > 1 else conv_state
    return out.astype(x.dtype), new_state


def recurrent_block(params: Dict, x: jax.Array, conv_state: jax.Array,
                    h_state: jax.Array,
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Griffin recurrent block. x: [B,T,D] -> (y, conv_state, h_state)."""
    from repro.kernels import ops as kops

    branch = x @ params["w_in"]                # [B,T,W] recurrent branch
    gate = jax.nn.gelu(x @ params["w_gate_branch"])
    branch, conv_state = causal_conv1d(params, branch, conv_state)
    r = jax.nn.sigmoid(branch @ params["w_a"] + params["b_a"])
    i = jax.nn.sigmoid(branch @ params["w_x"] + params["b_x"])
    y, h_state = kops.rglru_scan(branch, params["a_log"], r, i, h_state)
    y = y.astype(x.dtype) * gate
    return y @ params["w_out"], conv_state, h_state
