"""Model substrate: configs, backbone, mixers, attention."""
from .backbone import Backbone
from .config import (ARCH_NAMES, SHAPES, LayerGroup, ModelConfig,
                     ShapeConfig, all_configs, get_config, reduced, register)
from .partition import IDENTITY_PLAN, PartitionPlan

__all__ = ["Backbone", "ARCH_NAMES", "SHAPES", "LayerGroup", "ModelConfig",
           "ShapeConfig", "all_configs", "get_config", "reduced", "register",
           "IDENTITY_PLAN", "PartitionPlan"]
