"""RWKV-6 ("Finch") blocks: data-dependent-decay linear attention.

Time mixing maintains a per-head matrix state ``S ∈ R^{hd×hd}``:

    y_t = (S_{t-1} + (u ⊙ k_t) v_tᵀ)ᵀ r_t
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

with *data-dependent* decay ``w_t = exp(-exp(w0 + A_w tanh(x̃_t B_w)))`` —
the Finch contribution — plus LoRA-style data-dependent token-shift (ddlerp).
The sequence recurrence runs through ``repro.kernels.ops.rwkv6_scan`` (a
chunked Pallas kernel on TPU; a ``lax.scan`` fallback elsewhere).

State is O(1) in sequence length, which is why rwkv6 serves the ``long_500k``
cell that full-attention archs must skip.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import rms_norm


def _lora(x, a, b):
    """LoRA modulation: tanh(x @ a) @ b."""
    return jnp.tanh(x @ a) @ b


def _ddlerp(x, x_prev, mu, a, b):
    """Finch data-dependent lerp between x_t and x_{t-1}."""
    base = x_prev + (x - x_prev) * mu
    mix = mu + _lora(base, a, b)
    return x_prev + (x - x_prev) * mix


def time_mix(params: Dict, x: jax.Array, shift_state: jax.Array,
             wkv_state: jax.Array, n_heads: int, head_dim: int,
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """RWKV-6 attention analogue.

    x: [B,T,D]; shift_state: [B,D] (x_{-1}); wkv_state: [B,H,hd,hd].
    Returns (y, new_shift_state, new_wkv_state).
    """
    from repro.kernels import ops as kops

    B, T, D = x.shape
    H, hd = n_heads, head_dim
    x_prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)

    names = ("r", "k", "v", "g", "w")
    mixed = {
        n: _ddlerp(x, x_prev, params[f"mu_{n}"], params["dd_a"],
                   params[f"dd_b_{n}"])
        for n in names
    }
    r = (mixed["r"] @ params["w_r"]).reshape(B, T, H, hd)
    k = (mixed["k"] @ params["w_k"]).reshape(B, T, H, hd)
    v = (mixed["v"] @ params["w_v"]).reshape(B, T, H, hd)
    g = jax.nn.silu(mixed["g"] @ params["w_g"])
    # data-dependent decay (the Finch mechanism)
    w_raw = params["w0"] + _lora(mixed["w"], params["wd_a"], params["wd_b"])
    w = jnp.exp(-jnp.exp(w_raw.astype(jnp.float32))).reshape(B, T, H, hd)

    y, wkv_state = kops.rwkv6_scan(r, k, v, w, params["u"].reshape(H, hd),
                                   wkv_state)
    # per-head group norm; note H may be TP-padded so H*hd >= D
    y = y.reshape(B, T, H, hd)
    y = rms_norm(y, params["ln_x"].reshape(H, hd), eps=1e-5)
    y = y.reshape(B, T, H * hd) * g
    return y @ params["w_o"], x[:, -1, :], wkv_state


def channel_mix(params: Dict, x: jax.Array, shift_state: jax.Array,
                ) -> Tuple[jax.Array, jax.Array]:
    """RWKV-6 FFN analogue (squared-ReLU with receptance gate)."""
    x_prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    xk = x_prev + (x - x_prev) * params["mu_k"]
    xr = x_prev + (x - x_prev) * params["mu_r"]
    rgate = jax.nn.sigmoid(xr @ params["w_rgate"])
    hidden = jnp.square(jax.nn.relu(xk @ params["w_in"]))
    return rgate * (hidden @ params["w_out"]), x[:, -1, :]
