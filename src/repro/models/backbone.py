"""The shared decoder backbone: one substrate, ten architectures.

Every architecture is a stack of *segments* (``LayerGroup``); each segment is
``lax.scan``-ned over its repeat axis with stacked parameters, so the HLO is
small and compile times stay flat in depth. Heterogeneous patterns (gemma2's
local/global alternation, recurrentgemma's rec-rec-local blocks, whisper's
enc/dec split) are homogeneous *within* a scan body by construction.

Entry points:

* ``loss_fn(params, batch)``      — training loss (causal LM / enc-dec LM)
* ``prefill(params, batch)``      — run the context, return last-token logits
  plus a filled decode cache
* ``decode_step(params, cache, tokens)`` — one token with a KV/state cache

The backbone is mesh-agnostic: distribution enters only through the
``sharder`` callback (activation sharding constraints) and the
:class:`~repro.models.partition.PartitionPlan` (TP padding/replication).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import rglru as rg
from . import rwkv6 as rw
from .attention import flash_attention_jnp
from .common import (dense_init, embed_init, rms_norm, softcap,
                     stable_cross_entropy)
from .config import LayerGroup, ModelConfig
from .ffn import gated_mlp, moe_mlp
from .partition import IDENTITY_PLAN, PartitionPlan

Params = Dict[str, Any]
AUX_COEF = 0.01
_RWKV_LORA = 64


def _no_shard(x: jax.Array, name: str) -> jax.Array:
    return x


class Backbone:
    def __init__(self, cfg: ModelConfig, plan: PartitionPlan = IDENTITY_PLAN,
                 *, compute_dtype=jnp.bfloat16, param_dtype=jnp.float32,
                 remat: bool = True,
                 sharder: Callable[[jax.Array, str], jax.Array] = _no_shard,
                 param_gather: Optional[Callable[[Params], Params]] = None,
                 attn_impl: str = "auto",
                 moe_impl: str = "gspmd",
                 remat_policy: str = "full",
                 mesh=None, dp_axes: Tuple[str, ...] = ()):
        plan.check(cfg)
        self.cfg = cfg
        self.plan = plan
        self.compute_dtype = compute_dtype
        self.param_dtype = param_dtype
        self.remat = remat
        self.remat_policy = remat_policy
        self.shard = sharder
        self.param_gather = param_gather
        self.attn_impl = attn_impl
        self.moe_impl = moe_impl
        self.mesh = mesh
        self.dp_axes = dp_axes
        if moe_impl == "ep" and cfg.ffn_kind == "moe":
            from .moe_ep import virtualization
            self.moe_V, self.moe_split = virtualization(cfg, plan.tp)
        else:
            self.moe_V, self.moe_split = cfg.n_experts, 1
        self.H = plan.eff_heads(cfg)
        self.KV = plan.eff_kv_heads(cfg)
        self.hd = cfg.hd
        self.Vp = plan.eff_vocab(cfg)
        self.rwkv_H = plan.eff_rwkv_heads(cfg)
        self.W = cfg.rglru_width or cfg.d_model

    # ------------------------------------------------------------------ #
    # Parameter construction                                             #
    # ------------------------------------------------------------------ #
    def _leaf_specs(self, kind: str) -> Dict[str, Tuple[Tuple[int, ...], str]]:
        cfg = self.cfg
        D, F = cfg.d_model, cfg.d_ff
        H, KV, hd = self.H, self.KV, self.hd
        specs: Dict[str, Tuple[Tuple[int, ...], str]] = {}

        def attn(prefix: str = "") -> None:
            specs[f"{prefix}wq"] = ((D, H * hd), "dense")
            specs[f"{prefix}wk"] = ((D, KV * hd), "dense")
            specs[f"{prefix}wv"] = ((D, KV * hd), "dense")
            specs[f"{prefix}wo"] = ((H * hd, D), "dense")
            if cfg.qkv_bias:
                specs[f"{prefix}bq"] = ((H * hd,), "zero")
                specs[f"{prefix}bk"] = ((KV * hd,), "zero")
                specs[f"{prefix}bv"] = ((KV * hd,), "zero")
            if cfg.qk_norm:
                specs[f"{prefix}q_norm"] = ((hd,), "zero")
                specs[f"{prefix}k_norm"] = ((hd,), "zero")

        def dense_ffn() -> None:
            specs["ln2"] = ((D,), "zero")
            if cfg.ffn_kind in ("swiglu", "geglu"):
                specs["w_gate"] = ((D, F), "dense")
                specs["w_up"] = ((D, F), "dense")
                specs["w_down"] = ((F, D), "dense")
            else:  # gelu (whisper)
                specs["w_gate"] = ((D, F), "dense")
                specs["b_gate"] = ((F,), "zero")
                specs["w_down"] = ((F, D), "dense")
                specs["b_down"] = ((D,), "zero")

        def moe_ffn() -> None:
            E, Fe = cfg.n_experts, cfg.moe_d_ff or F
            # EP path stores VIRTUALIZED experts [V, D, Fe/split] (an exact
            # column split; see moe_ep.py) so the expert dim always shards
            V, split = self.moe_V, self.moe_split
            Fv = Fe // split
            specs["ln2"] = ((D,), "zero")
            specs["router"] = ((D, E), "dense")
            specs["w_gate"] = ((V, D, Fv), "dense")
            specs["w_up"] = ((V, D, Fv), "dense")
            specs["w_down"] = ((V, Fv, D), "dense")

        if kind in ("attn", "local", "enc"):
            specs["ln1"] = ((D,), "zero")
            attn()
            moe_ffn() if cfg.ffn_kind == "moe" else dense_ffn()
        elif kind == "dec":
            specs["ln1"] = ((D,), "zero")
            attn()
            specs["ln_cross"] = ((D,), "zero")
            attn("c_")
            dense_ffn()
        elif kind == "rwkv":
            Hr, hdr = self.rwkv_H, cfg.rwkv_head_dim
            Dr = Hr * hdr
            r = _RWKV_LORA
            specs["ln1"] = ((D,), "zero")
            for n in ("r", "k", "v", "g", "w"):
                specs[f"mu_{n}"] = ((D,), "zero")
                specs[f"dd_b_{n}"] = ((32, D), "zero")
            specs["dd_a"] = ((D, 32), "dense")
            specs["w_r"] = ((D, Dr), "dense")
            specs["w_k"] = ((D, Dr), "dense")
            specs["w_v"] = ((D, Dr), "dense")
            specs["w_g"] = ((D, Dr), "dense")
            specs["w0"] = ((Dr,), "zero")
            specs["wd_a"] = ((D, r), "dense")
            specs["wd_b"] = ((r, Dr), "zero")
            specs["u"] = ((Dr,), "zero")
            specs["ln_x"] = ((Dr,), "zero")
            specs["w_o"] = ((Dr, D), "dense")
            specs["ln2"] = ((D,), "zero")
            specs["mu_k2"] = ((D,), "zero")
            specs["mu_r2"] = ((D,), "zero")
            specs["w_in"] = ((D, F), "dense")
            specs["w_out"] = ((F, D), "dense")
            specs["w_rgate"] = ((D, D), "dense")
        elif kind == "rec":
            W = self.W
            NB = cfg.n_heads  # gate blocks
            wb = W // NB
            specs["ln1"] = ((D,), "zero")
            specs["w_in"] = ((D, W), "dense")
            specs["w_gate_branch"] = ((D, W), "dense")
            specs["conv_w"] = ((cfg.conv1d_width, W), "dense")
            specs["conv_b"] = ((W,), "zero")
            specs["gw_a"] = ((NB, wb, wb), "dense")
            specs["gb_a"] = ((W,), "zero")
            specs["gw_x"] = ((NB, wb, wb), "dense")
            specs["gb_x"] = ((W,), "zero")
            specs["a_log"] = ((W,), "lru")
            specs["w_out"] = ((W, D), "dense")
            dense_ffn()
        else:  # pragma: no cover
            raise ValueError(f"unknown layer kind {kind!r}")
        return specs

    def _init_leaf(self, key, shape, kind_init):
        if kind_init == "zero":
            return jnp.zeros(shape, self.param_dtype)
        if kind_init == "lru":
            # Λ init so decay a ∈ (0.9, 0.999) roughly
            import numpy as np
            u = jax.random.uniform(key, shape, jnp.float32, 0.05, 0.6)
            return jnp.log(jnp.expm1(u)).astype(self.param_dtype)  # inv-softplus
        return dense_init(key, shape, dtype=self.param_dtype)

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        params: Params = {}
        key, ek = jax.random.split(key)
        params["embed"] = {"tok": embed_init(ek, (self.Vp, cfg.d_model),
                                             self.param_dtype)}
        if cfg.is_enc_dec:
            key, pk = jax.random.split(key)
            params["embed"]["enc_pos"] = embed_init(
                pk, (cfg.enc_seq, cfg.d_model), self.param_dtype)
        if not cfg.tie_embeddings:
            key, hk = jax.random.split(key)
            params["lm_head"] = dense_init(hk, (cfg.d_model, self.Vp),
                                           dtype=self.param_dtype)
        params["final_norm"] = jnp.zeros((cfg.d_model,), self.param_dtype)
        for gi, group in enumerate(cfg.groups):
            gp: Dict[str, Any] = {}
            for si, kind in enumerate(group.pattern):
                sub: Dict[str, Any] = {}
                for name, (shape, init_kind) in self._leaf_specs(kind).items():
                    key, lk = jax.random.split(key)
                    sub[name] = self._init_leaf(lk, (group.repeat,) + shape,
                                                init_kind)
                gp[f"s{si}"] = sub
            params[f"g{gi}"] = gp
        return params

    def param_specs(self) -> Params:
        """ShapeDtypeStruct tree (no allocation) for AOT lowering."""
        return jax.eval_shape(lambda k: self.init(k), jax.random.PRNGKey(0))

    # ------------------------------------------------------------------ #
    # Sublayer forward functions                                          #
    # ------------------------------------------------------------------ #
    def _qkv(self, p, h, prefix: str = ""):
        cfg = self.cfg
        B, S, _ = h.shape
        q = h @ p[f"{prefix}wq"]
        k = h @ p[f"{prefix}wk"]
        v = h @ p[f"{prefix}wv"]
        if cfg.qkv_bias:
            q = q + p[f"{prefix}bq"]
            k = k + p[f"{prefix}bk"]
            v = v + p[f"{prefix}bv"]
        q = self.shard(q, "act_heads").reshape(B, S, self.H, self.hd)
        k = k.reshape(B, S, self.KV, self.hd)
        v = v.reshape(B, S, self.KV, self.hd)
        if cfg.qk_norm:
            q = rms_norm(q, p[f"{prefix}q_norm"], cfg.norm_eps)
            k = rms_norm(k, p[f"{prefix}k_norm"], cfg.norm_eps)
        return q, k, v

    def _attn_sublayer(self, p, x, kind: str, positions) -> jax.Array:
        """Self-attention residual branch (train/prefill path)."""
        cfg = self.cfg
        B, S, D = x.shape
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = self._qkv(p, h)
        if kind != "enc":
            from .common import apply_rope
            q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.rotary_pct)
        window = cfg.attn_window if kind == "local" else None
        o = flash_attention_jnp(
            q, k, v, causal=(kind != "enc"), window=window,
            logit_cap=cfg.attn_logit_softcap,
            q_positions=positions, kv_positions=positions)
        o = o.reshape(B, S, self.H * self.hd) @ p["wo"]
        return self.shard(o, "act_hidden")

    def _cross_sublayer(self, p, x, enc_kv) -> jax.Array:
        cfg = self.cfg
        B, S, D = x.shape
        h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        q = (h @ p["c_wq"])
        if cfg.qkv_bias:
            q = q + p["c_bq"]
        q = q.reshape(B, S, self.H, self.hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["c_q_norm"], cfg.norm_eps)
        ck, cv = enc_kv
        o = flash_attention_jnp(q, ck, cv, causal=False)
        return o.reshape(B, S, self.H * self.hd) @ p["c_wo"]

    def _ffn_sublayer(self, p, x) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.ffn_kind == "moe":
            if self.moe_impl == "ep":
                from .moe_ep import moe_mlp_ep
                y, aux = moe_mlp_ep(p, h, cfg, self.mesh, self.dp_axes)
            else:
                y, aux = moe_mlp(p, h, cfg, self.shard)
        else:
            y, aux = gated_mlp(p, h, cfg.ffn_kind), jnp.zeros((), jnp.float32)
        return self.shard(y, "act_hidden"), aux

    # -- full layer bodies (train/prefill) -------------------------------------
    def _layer_fwd(self, p, x, kind: str, positions, enc_kv=None
                   ) -> Tuple[jax.Array, jax.Array]:
        """Returns (x, aux_loss). Stateless path (no cache)."""
        cfg = self.cfg
        if kind == "rwkv":
            B, _, D = x.shape
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            shift0 = jnp.zeros((B, D), x.dtype)
            wkv0 = jnp.zeros((B, self.rwkv_H, cfg.rwkv_head_dim,
                              cfg.rwkv_head_dim), jnp.float32)
            y, _, _ = rw.time_mix(p, h, shift0, wkv0, self.rwkv_H,
                                  cfg.rwkv_head_dim)
            x = x + y.astype(x.dtype)
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            y, _ = rw.channel_mix(
                {"mu_k": p["mu_k2"], "mu_r": p["mu_r2"], "w_in": p["w_in"],
                 "w_out": p["w_out"], "w_rgate": p["w_rgate"]},
                h, jnp.zeros((B, D), x.dtype))
            return x + y, jnp.zeros((), jnp.float32)
        if kind == "rec":
            B, _, D = x.shape
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            conv0 = jnp.zeros((B, cfg.conv1d_width - 1, self.W), x.dtype)
            h0 = jnp.zeros((B, self.W), jnp.float32)
            y, _, _ = self._rglru_apply(p, h, conv0, h0)
            x = x + y.astype(x.dtype)
            y, aux = self._ffn_sublayer(p, x)
            return x + y, aux
        # attention-family kinds
        x = x + self._attn_sublayer(p, x, kind, positions)
        if kind == "dec":
            x = x + self._cross_sublayer(p, x, enc_kv)
        y, aux = self._ffn_sublayer(p, x)
        return x + y, aux

    def _rglru_apply(self, p, h, conv_state, h_state):
        """Griffin recurrent block with block-diagonal gates."""
        cfg = self.cfg
        NB = cfg.n_heads
        W = self.W
        wb = W // NB
        branch = h @ p["w_in"]
        gate = jax.nn.gelu(h @ p["w_gate_branch"])
        branch, conv_state = rg.causal_conv1d(p, branch, conv_state)
        bb = branch.reshape(*branch.shape[:-1], NB, wb)
        r = jax.nn.sigmoid(
            jnp.einsum("...nw,nwv->...nv", bb, p["gw_a"]).reshape(branch.shape)
            + p["gb_a"])
        i = jax.nn.sigmoid(
            jnp.einsum("...nw,nwv->...nv", bb, p["gw_x"]).reshape(branch.shape)
            + p["gb_x"])
        from repro.kernels import ops as kops
        y, h_state = kops.rglru_scan(branch, p["a_log"], r, i, h_state)
        y = y.astype(h.dtype) * gate
        return y @ p["w_out"], conv_state, h_state

    # ------------------------------------------------------------------ #
    # Training forward / loss                                             #
    # ------------------------------------------------------------------ #
    def _embed_tokens(self, params, tokens) -> jax.Array:
        cfg = self.cfg
        x = jnp.take(params["embed"]["tok"], tokens, axis=0)
        x = x.astype(self.compute_dtype)
        return x * jnp.sqrt(jnp.asarray(cfg.d_model, self.compute_dtype)) \
            if cfg.embed_scale else x

    def _logits(self, params, x) -> jax.Array:
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"].astype(self.compute_dtype),
                     cfg.norm_eps)
        head = (params["embed"]["tok"].T if cfg.tie_embeddings
                else params["lm_head"]).astype(self.compute_dtype)
        logits = x @ head
        logits = self.shard(logits, "logits")
        if self.Vp != cfg.vocab:  # mask padded vocab columns
            mask = jnp.arange(self.Vp) < cfg.vocab
            logits = jnp.where(mask, logits, -1e30)
        return logits

    def _cast_group(self, gp):
        out = jax.tree_util.tree_map(
            lambda a: a.astype(self.compute_dtype)
            if a.dtype in (jnp.float32, jnp.bfloat16, jnp.float16) else a, gp)
        if self.param_gather is not None:
            # per-layer weight all-gather (prefetch / early-release schedule)
            out = self.param_gather(out)
        return out


    def _checkpoint(self, fn):
        """Wrap a scan body in jax.checkpoint per the configured policy."""
        if not self.remat:
            return fn
        if self.remat_policy == "dots":
            pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            return jax.checkpoint(fn, policy=pol)
        return jax.checkpoint(fn)

    def _run_groups(self, params, x, positions, enc_kv=None):
        """Scan every segment; returns (x, total_aux)."""
        total_aux = jnp.zeros((), jnp.float32)
        for gi, group in enumerate(self.cfg.groups):
            gp = params[f"g{gi}"]

            def body(carry, layer_params, _kinds=group.pattern):
                h, aux = carry
                lp = self._cast_group(layer_params)
                for si, kind in enumerate(_kinds):
                    h, a = self._layer_fwd(lp[f"s{si}"], h, kind, positions,
                                           enc_kv)
                    aux = aux + a
                return (h, aux), None

            scan_body = self._checkpoint(body)
            (x, total_aux), _ = jax.lax.scan(
                scan_body, (x, total_aux), gp)
        return x, total_aux

    def _encode(self, params, frames) -> jax.Array:
        """Whisper encoder over precomputed (stub-frontend) frames."""
        cfg = self.cfg
        x = frames.astype(self.compute_dtype)
        x = x + params["embed"]["enc_pos"].astype(self.compute_dtype)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        total_aux = jnp.zeros((), jnp.float32)
        for gi, group in enumerate(cfg.groups):
            if "enc" not in group.pattern:
                continue
            gp = params[f"g{gi}"]

            def body(carry, layer_params, _kinds=group.pattern):
                h, aux = carry
                lp = self._cast_group(layer_params)
                for si, kind in enumerate(_kinds):
                    h, a = self._layer_fwd(lp[f"s{si}"], h, kind, positions)
                    aux = aux + a
                return (h, aux), None

            scan_body = self._checkpoint(body)
            (x, total_aux), _ = jax.lax.scan(scan_body, (x, total_aux), gp)
        return x

    def _decoder_groups(self):
        return [(gi, g) for gi, g in enumerate(self.cfg.groups)
                if "enc" not in g.pattern]

    def _run_decoder(self, params, x, positions, enc_out=None):
        total_aux = jnp.zeros((), jnp.float32)
        enc_kv = None
        if enc_out is not None:
            enc_kv = enc_out  # per-layer cross kv computed inside sublayer
        for gi, group in self._decoder_groups():
            gp = params[f"g{gi}"]

            def body(carry, layer_params, _kinds=group.pattern):
                h, aux = carry
                lp = self._cast_group(layer_params)
                for si, kind in enumerate(_kinds):
                    ekv = None
                    if kind == "dec":
                        B, Se, D = enc_kv.shape
                        ck = (enc_kv @ lp[f"s{si}"]["c_wk"]).reshape(
                            B, Se, self.KV, self.hd)
                        cv = (enc_kv @ lp[f"s{si}"]["c_wv"]).reshape(
                            B, Se, self.KV, self.hd)
                        ekv = (ck, cv)
                    h, a = self._layer_fwd(lp[f"s{si}"], h, kind, positions,
                                           ekv)
                    aux = aux + a
                return (h, aux), None

            scan_body = self._checkpoint(body)
            (x, total_aux), _ = jax.lax.scan(scan_body, (x, total_aux), gp)
        return x, total_aux

    def loss_fn(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        tokens = batch["tokens"]
        labels = batch["labels"]
        x = self._embed_tokens(params, tokens)
        x = self.shard(x, "act_hidden")
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        if cfg.is_enc_dec:
            enc_out = self._encode(params, batch["enc_frames"])
            x, aux = self._run_decoder(params, x, positions, enc_out)
        else:
            x, aux = self._run_groups(params, x, positions)
        logits = self._logits(params, x)
        loss = stable_cross_entropy(logits, labels, cfg.final_logit_softcap)
        return loss + AUX_COEF * aux

    # ------------------------------------------------------------------ #
    # Serving: prefill + decode                                           #
    # ------------------------------------------------------------------ #
    def cache_len(self, kind: str, ctx: int) -> int:
        if kind == "local":
            return min(self.cfg.attn_window or ctx, ctx)
        return ctx

    def init_cache(self, B: int, ctx: int, dtype=None) -> Params:
        cfg = self.cfg
        dtype = dtype or self.compute_dtype
        cache: Params = {"pos": jnp.zeros((), jnp.int32)}
        for gi, group in self._decoder_groups():
            gc: Dict[str, Any] = {}
            R = group.repeat
            for si, kind in enumerate(group.pattern):
                if kind in ("attn", "local", "dec"):
                    C = self.cache_len(kind, ctx)
                    sub = {
                        "k": jnp.zeros((R, B, C, self.KV, self.hd), dtype),
                        "v": jnp.zeros((R, B, C, self.KV, self.hd), dtype),
                        "kpos": jnp.full((R, C), -1, jnp.int32),
                    }
                    if kind == "dec":
                        sub["ck"] = jnp.zeros((R, B, cfg.enc_seq, self.KV,
                                               self.hd), dtype)
                        sub["cv"] = jnp.zeros((R, B, cfg.enc_seq, self.KV,
                                               self.hd), dtype)
                elif kind == "rwkv":
                    sub = {
                        "shift1": jnp.zeros((R, B, cfg.d_model), dtype),
                        "wkv": jnp.zeros((R, B, self.rwkv_H,
                                          cfg.rwkv_head_dim,
                                          cfg.rwkv_head_dim), jnp.float32),
                        "shift2": jnp.zeros((R, B, cfg.d_model), dtype),
                    }
                elif kind == "rec":
                    sub = {
                        "conv": jnp.zeros((R, B, cfg.conv1d_width - 1, self.W),
                                          dtype),
                        "h": jnp.zeros((R, B, self.W), jnp.float32),
                    }
                else:
                    sub = {}
                gc[f"s{si}"] = sub
            cache[f"g{gi}"] = gc
        return cache

    def _layer_decode(self, p, x, kind: str, sub_cache, pos):
        """One-token step. x: [B,1,D]. Returns (x, new_sub_cache)."""
        cfg = self.cfg
        B = x.shape[0]
        if kind == "rwkv":
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            y, s1, wkv = rw.time_mix(p, h, sub_cache["shift1"],
                                     sub_cache["wkv"], self.rwkv_H,
                                     cfg.rwkv_head_dim)
            x = x + y.astype(x.dtype)
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            y, s2 = rw.channel_mix(
                {"mu_k": p["mu_k2"], "mu_r": p["mu_r2"], "w_in": p["w_in"],
                 "w_out": p["w_out"], "w_rgate": p["w_rgate"]},
                h, sub_cache["shift2"])
            x = x + y
            return x, {"shift1": s1, "wkv": wkv, "shift2": s2.astype(
                sub_cache["shift2"].dtype)}
        if kind == "rec":
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            y, conv, hs = self._rglru_apply(p, h, sub_cache["conv"],
                                            sub_cache["h"])
            x = x + y.astype(x.dtype)
            y, _ = self._ffn_sublayer(p, x)
            return x + y, {"conv": conv.astype(sub_cache["conv"].dtype),
                           "h": hs}
        # attention family
        from .common import apply_rope
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = self._qkv(p, h)
        posv = pos[None].astype(jnp.int32) if pos.ndim == 0 else pos
        q = apply_rope(q, posv, cfg.rope_theta, cfg.rotary_pct)
        k = apply_rope(k, posv, cfg.rope_theta, cfg.rotary_pct)
        C = sub_cache["k"].shape[1]  # [B, C, KV, hd] after scan slicing
        slot = pos % C
        ck = sub_cache["k"].astype(x.dtype).at[:, slot].set(k[:, 0])
        cv = sub_cache["v"].astype(x.dtype).at[:, slot].set(v[:, 0])
        kpos = sub_cache["kpos"].at[slot].set(pos.astype(jnp.int32))
        window = cfg.attn_window if kind == "local" else None
        o = flash_attention_jnp(
            q, ck, cv, causal=True, window=window,
            logit_cap=cfg.attn_logit_softcap,
            q_positions=posv, kv_positions=kpos,
            q_chunk=1, kv_chunk=max(1024, min(4096, C)))
        o = o.reshape(B, 1, self.H * self.hd) @ p["wo"]
        x = x + self.shard(o, "act_hidden")
        new_sub = {"k": ck.astype(sub_cache["k"].dtype),
                   "v": cv.astype(sub_cache["v"].dtype), "kpos": kpos}
        if kind == "dec":
            h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
            q = (h @ p["c_wq"]).reshape(B, 1, self.H, self.hd)
            if cfg.qk_norm:
                q = rms_norm(q, p["c_q_norm"], cfg.norm_eps)
            o = flash_attention_jnp(q, sub_cache["ck"].astype(x.dtype),
                                    sub_cache["cv"].astype(x.dtype),
                                    causal=False, q_chunk=1)
            x = x + (o.reshape(B, 1, self.H * self.hd) @ p["c_wo"])
            new_sub["ck"] = sub_cache["ck"]
            new_sub["cv"] = sub_cache["cv"]
        y, _ = self._ffn_sublayer(p, x)
        return x + y, new_sub

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array
                    ) -> Tuple[jax.Array, Params]:
        """tokens: [B, 1] -> (logits [B, 1, Vp], new cache)."""
        pos = cache["pos"]
        x = self._embed_tokens(params, tokens)
        new_cache: Params = {"pos": pos + 1}
        for gi, group in self._decoder_groups():
            gp = params[f"g{gi}"]
            gc = cache[f"g{gi}"]

            def body(carry, xs, _kinds=group.pattern):
                h = carry
                layer_params, layer_cache = xs
                lp = self._cast_group(layer_params)
                new_lc = {}
                for si, kind in enumerate(_kinds):
                    h, nc = self._layer_decode(lp[f"s{si}"], h, kind,
                                               layer_cache[f"s{si}"], pos)
                    new_lc[f"s{si}"] = nc
                return h, new_lc

            x, ngc = jax.lax.scan(body, x, (gp, gc))
            new_cache[f"g{gi}"] = ngc
        logits = self._logits(params, x)
        return logits, new_cache

    def prefill(self, params: Params, batch: Dict[str, jax.Array], ctx: int
                ) -> Tuple[jax.Array, Params]:
        """Run the full context; return (last-token logits, filled cache)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed_tokens(params, tokens)
        positions = jnp.arange(S, dtype=jnp.int32)
        enc_out = None
        if cfg.is_enc_dec:
            enc_out = self._encode(params, batch["enc_frames"])
        new_cache: Params = {"pos": jnp.asarray(S, jnp.int32)}
        for gi, group in self._decoder_groups():
            gp = params[f"g{gi}"]

            def body(h, layer_params, _kinds=group.pattern):
                lp = self._cast_group(layer_params)
                lc = {}
                for si, kind in enumerate(_kinds):
                    if kind in ("attn", "local", "dec"):
                        # recompute k/v to fill the cache for this layer
                        hh = rms_norm(h, lp[f"s{si}"]["ln1"], cfg.norm_eps)
                        _, k, v = self._qkv(lp[f"s{si}"], hh)
                        from .common import apply_rope
                        k = apply_rope(k, positions, cfg.rope_theta,
                                       cfg.rotary_pct)
                        C = self.cache_len(kind, ctx)
                        n = min(C, S)
                        sel = positions[S - n:]
                        slots = sel % C
                        ck = jnp.zeros((B, C, self.KV, self.hd), x.dtype
                                       ).at[:, slots].set(k[:, S - n:])
                        # v without rope
                        cv = jnp.zeros((B, C, self.KV, self.hd), x.dtype
                                       ).at[:, slots].set(v[:, S - n:])
                        kpos = jnp.full((C,), -1, jnp.int32
                                        ).at[slots].set(sel)
                        sub = {"k": ck, "v": cv, "kpos": kpos}
                        ekv = None
                        if kind == "dec":
                            Se = enc_out.shape[1]
                            eck = (enc_out @ lp[f"s{si}"]["c_wk"]).reshape(
                                B, Se, self.KV, self.hd)
                            ecv = (enc_out @ lp[f"s{si}"]["c_wv"]).reshape(
                                B, Se, self.KV, self.hd)
                            sub["ck"], sub["cv"] = eck, ecv
                            ekv = (eck, ecv)
                        h, _ = self._layer_fwd(lp[f"s{si}"], h, kind,
                                               positions, ekv)
                        lc[f"s{si}"] = sub
                    elif kind == "rwkv":
                        hh = rms_norm(h, lp[f"s{si}"]["ln1"], cfg.norm_eps)
                        shift0 = jnp.zeros((B, cfg.d_model), h.dtype)
                        wkv0 = jnp.zeros((B, self.rwkv_H, cfg.rwkv_head_dim,
                                          cfg.rwkv_head_dim), jnp.float32)
                        y, s1, wkv = rw.time_mix(lp[f"s{si}"], hh, shift0,
                                                 wkv0, self.rwkv_H,
                                                 cfg.rwkv_head_dim)
                        h = h + y.astype(h.dtype)
                        hh = rms_norm(h, lp[f"s{si}"]["ln2"], cfg.norm_eps)
                        y, s2 = rw.channel_mix(
                            {"mu_k": lp[f"s{si}"]["mu_k2"],
                             "mu_r": lp[f"s{si}"]["mu_r2"],
                             "w_in": lp[f"s{si}"]["w_in"],
                             "w_out": lp[f"s{si}"]["w_out"],
                             "w_rgate": lp[f"s{si}"]["w_rgate"]},
                            hh, jnp.zeros((B, cfg.d_model), h.dtype))
                        h = h + y
                        lc[f"s{si}"] = {"shift1": s1, "wkv": wkv,
                                        "shift2": s2.astype(h.dtype)}
                    elif kind == "rec":
                        hh = rms_norm(h, lp[f"s{si}"]["ln1"], cfg.norm_eps)
                        conv0 = jnp.zeros((B, cfg.conv1d_width - 1, self.W),
                                          h.dtype)
                        h0 = jnp.zeros((B, self.W), jnp.float32)
                        y, conv, hs = self._rglru_apply(lp[f"s{si}"], hh,
                                                        conv0, h0)
                        h = h + y.astype(h.dtype)
                        y, _ = self._ffn_sublayer(lp[f"s{si}"], h)
                        h = h + y
                        lc[f"s{si}"] = {"conv": conv.astype(h.dtype), "h": hs}
                return h, lc

            scan_body = self._checkpoint(body)
            x, gc = jax.lax.scan(scan_body, x, gp)
            new_cache[f"g{gi}"] = gc
        logits = self._logits(params, x[:, -1:, :])
        return logits, new_cache
