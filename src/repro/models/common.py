"""Shared model building blocks: norms, RoPE, activations, initializers."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm in fp32 accumulation, cast back to input dtype."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# --------------------------------------------------------------------------- #
# RoPE                                                                        #
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float, rotary_pct: float = 1.0
               ) -> Tuple[int, jax.Array]:
    """Return (#rotary dims, inverse frequencies [rot/2])."""
    rot = int(head_dim * rotary_pct)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return rot, inv


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rotary_pct: float = 1.0) -> jax.Array:
    """Rotary embedding. ``x``: [..., S, H, hd]; ``positions``: [..., S]."""
    hd = x.shape[-1]
    rot, inv = rope_freqs(hd, theta, rotary_pct)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv[None, :]  # [..., S, rot/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]  # broadcast over heads
    cos = cos[..., :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# --------------------------------------------------------------------------- #
# Initialization                                                              #
# --------------------------------------------------------------------------- #
def dense_init(key: jax.Array, shape: Tuple[int, ...], in_axis: int = -2,
               dtype=jnp.float32) -> jax.Array:
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, shape: Tuple[int, ...], dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def stable_cross_entropy(logits: jax.Array, labels: jax.Array,
                         final_cap: Optional[float] = None) -> jax.Array:
    """Mean token cross-entropy; fp32 logsumexp; optional final softcap."""
    logits = logits.astype(jnp.float32)
    logits = softcap(logits, final_cap)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def gqa_repeat(kv: jax.Array, n_heads: int) -> jax.Array:
    """Broadcast KV heads to query heads: [..., n_kv, hd] -> [..., n_heads, hd]."""
    n_kv = kv.shape[-2]
    if n_kv == n_heads:
        return kv
    rep = n_heads // n_kv
    return jnp.repeat(kv, rep, axis=-2)
