"""Expert-parallel MoE via shard_map (the optimized path; DESIGN.md §2.2).

The control-flow-model transcription of routing: experts are *homed* on
model shards and tokens delegate computation to their experts' home shard —
no capacity buffer ever crosses the ICI. Per device everything is local
(router, top-k, scatter into the owned experts' capacity buffer, expert
FFN, gather-combine) except ONE ``psum`` over the model axis that merges
per-shard partial outputs (+ its transpose in backward).

When ``n_experts < tp`` each expert is split column-wise into
``split = tp / E`` *virtual experts* (TP inside the expert) — an exact
decomposition of the gated FFN, so every mesh size is served without
weight replication:

    silu(x Wg) * (x Wu) Wd  ==  Σ_h silu(x Wg_h) * (x Wu_h) Wd_h

Parameters are therefore STORED virtualized: ``[V, D, Fe/split]`` with the
virtual-expert dim sharded over "model" (and ZeRO over "data" on D).

Compared against the GSPMD scatter baseline (``ffn.moe_mlp``) in
EXPERIMENTS.md §Perf: it removes the TiB-scale involuntary-rematerialization
all-gathers/all-reduces the baseline suffers on both MoE archs.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .ffn import moe_capacity


def virtualization(cfg: ModelConfig, tp: int) -> Tuple[int, int]:
    """(V, split): virtual expert count and per-expert column split."""
    E = cfg.n_experts
    if E % tp == 0:
        return E, 1
    split = -(-tp // E)
    assert (E * split) % tp == 0, (E, tp)
    return E * split, split


def _local_moe(xt, router, w_gate, w_up, w_down, *, cfg: ModelConfig,
               V: int, split: int, tp: int, dp_axes: Tuple[str, ...]):
    """Per-device body (inside shard_map).

    xt: [T, D] (this data shard's tokens; replicated over model)
    router: [D, E]; w_*: [V_loc, D, Fe_v] / [V_loc, Fe_v, D] (owned virtuals)
    """
    T, D = xt.shape
    E, K = cfg.n_experts, cfg.top_k
    V_loc = V // tp
    m = jax.lax.axis_index("model")
    base = m * V_loc

    probs = jax.nn.softmax(
        (xt.astype(jnp.float32) @ router.astype(jnp.float32)), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)               # [T, K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # expand to virtual destinations: expert e -> virtuals e*split+h
    vidx = (gate_idx[..., None] * split
            + jnp.arange(split)[None, None, :])                 # [T, K, split]
    vflat = vidx.reshape(-1)                                    # [T*K*split]
    wflat = jnp.repeat(gate_vals.reshape(-1), split)            # [T*K*split]

    # global intra-virtual positions (identical on every shard: deterministic)
    C = moe_capacity(T, E, K, cfg.capacity_factor)
    onehot = jax.nn.one_hot(vflat, V, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1)
    pos_in_v = jnp.take_along_axis(pos, vflat[:, None], axis=1)[:, 0]

    own = (vflat >= base) & (vflat < base + V_loc)
    keep = own & (pos_in_v < C)
    slot_v = jnp.where(keep, vflat - base, 0)
    slot_c = jnp.where(keep, pos_in_v, 0)

    src = jnp.repeat(xt, K * split, axis=0)                     # [T*K*split, D]
    src = jnp.where(keep[:, None], src, 0)
    buf = jnp.zeros((V_loc, C, D), xt.dtype).at[slot_v, slot_c].add(
        src, mode="drop")

    h = jax.nn.silu(jnp.einsum("vcd,vdf->vcf", buf, w_gate)) \
        * jnp.einsum("vcd,vdf->vcf", buf, w_up)
    out_buf = jnp.einsum("vcf,vfd->vcd", h, w_down)             # [V_loc, C, D]

    gathered = out_buf[slot_v, slot_c]                          # [T*K*split, D]
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = jnp.sum((gathered * wflat[:, None].astype(gathered.dtype))
                .reshape(T, K * split, D), axis=1)
    y = jax.lax.psum(y, "model")                                # the one collective

    # Switch-style aux loss (identical across model shards; averaged over dp)
    density = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32),
                       axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * E
    if dp_axes:
        aux = jax.lax.pmean(aux, dp_axes)
    return y, aux


def moe_mlp_ep(params: Dict, x: jax.Array, cfg: ModelConfig, mesh: Mesh,
               dp_axes: Tuple[str, ...]) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE. x: [B, S, D] -> (y, aux).

    params["w_gate"]/["w_up"]: [V, D, Fe_v]; ["w_down"]: [V, Fe_v, D];
    ["router"]: [D, E]. Weights must already be gathered to their TP-only
    sharding (the per-layer ZeRO prefetch handles that upstream).
    """
    B, S, D = x.shape
    tp = mesh.shape.get("model", 1)
    V, split = virtualization(cfg, tp)
    dp = dp_axes if (B * S) % max(
        1, __import__("math").prod(mesh.shape[a] for a in dp_axes)) == 0 \
        and B > 1 else ()
    body = functools.partial(_local_moe, cfg=cfg, V=V, split=split, tp=tp,
                             dp_axes=dp)

    xt = x.reshape(B * S, D)
    tok_spec = P(dp or None, None)
    # jax >= 0.6 exposes shard_map at top level (check_vma kwarg); older
    # releases only have the experimental module (check_rep kwarg, inverted
    # meaning of neither — both just disable replication checking here).
    if hasattr(jax, "shard_map"):
        shard_map = functools.partial(jax.shard_map, check_vma=False)
    else:
        from jax.experimental.shard_map import shard_map as _sm
        shard_map = functools.partial(_sm, check_rep=False)
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec,
                  P(None, None),
                  P("model", None, None),
                  P("model", None, None),
                  P("model", None, None)),
        out_specs=(tok_spec, P()),
    )(xt, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return y.reshape(B, S, D), aux
