"""Feed-forward layers: gated MLPs and capacity-based MoE.

The MoE dispatch uses scatter-into-capacity-buffers (tokens routed into an
``[E, C, D]`` buffer by top-k index + intra-expert position), batched expert
einsums, and gather-combine. Under the production mesh the expert axis is
sharded over ``model`` (expert parallelism); XLA materializes the token
exchange as all-to-all-style collectives. Tokens overflowing an expert's
capacity are dropped (standard capacity-factor routing); the router keeps
an auxiliary load-balancing loss.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig


def gated_mlp(params: Dict, x: jax.Array, kind: str) -> jax.Array:
    """SwiGLU / GeGLU / GELU MLP. x: [..., D]."""
    if kind in ("swiglu", "geglu"):
        gate = x @ params["w_gate"]
        up = x @ params["w_up"]
        act = jax.nn.silu(gate) if kind == "swiglu" else jax.nn.gelu(gate)
        return (act * up) @ params["w_down"]
    hidden = jax.nn.gelu(x @ params["w_gate"] + params.get("b_gate", 0.0))
    out = hidden @ params["w_down"]
    if "b_down" in params:
        out = out + params["b_down"]
    return out


def moe_capacity(n_tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    cap = int(n_tokens * top_k / n_experts * capacity_factor)
    return max(cap, top_k, 8)


def moe_mlp(params: Dict, x: jax.Array, cfg: ModelConfig,
            shard=lambda a, name: a) -> Tuple[jax.Array, jax.Array]:
    """Top-k routed MoE. x: [B, S, D] -> (y, aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)
    router_logits = (xt.astype(jnp.float32) @
                     params["router"].astype(jnp.float32))       # [T, E]
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                # [T, K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)        # renormalize

    # ---- intra-expert positions via cumulative one-hot ----------------------
    C = moe_capacity(T, E, K, cfg.capacity_factor)
    flat_idx = gate_idx.reshape(-1)                              # [T*K]
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)        # [T*K, E]
    pos = jnp.cumsum(onehot, axis=0) - 1                         # running count
    pos_in_e = jnp.take_along_axis(pos, flat_idx[:, None], axis=1)[:, 0]
    keep = pos_in_e < C                                          # capacity drop
    safe_pos = jnp.where(keep, pos_in_e, 0)

    # ---- dispatch: scatter tokens into [E, C, D] ----------------------------
    src = jnp.repeat(xt, K, axis=0)                              # [T*K, D]
    src = jnp.where(keep[:, None], src, 0)
    buf = jnp.zeros((E, C, D), dtype=x.dtype)
    buf = buf.at[flat_idx, safe_pos].add(src, mode="drop")
    buf = shard(buf, "moe_buf")  # EP: expert axis over "model"

    # ---- expert computation (batched over experts) ---------------------------
    h_gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    h_up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])    # [E, C, D]
    out_buf = shard(out_buf, "moe_buf")

    # ---- combine: gather + weight ------------------------------------------
    gathered = out_buf[flat_idx, safe_pos]                       # [T*K, D]
    gathered = jnp.where(keep[:, None], gathered, 0)
    weights = gate_vals.reshape(-1)[:, None].astype(gathered.dtype)
    y = jnp.sum((gathered * weights).reshape(T, K, D), axis=1)

    # ---- auxiliary load-balancing loss (Switch-style) ------------------------
    density = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * E

    return y.reshape(B, S, D), aux
