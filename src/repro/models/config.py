"""Model / shape configuration system.

A :class:`ModelConfig` fully describes one architecture. Heterogeneous layer
stacks (gemma2's local/global alternation, recurrentgemma's rec-rec-attn
pattern, whisper's encoder/decoder) are expressed as *segments*: an ordered
list of ``LayerGroup(pattern, repeat)`` where ``pattern`` is a tuple of layer
kinds. Each group is scanned with parameters stacked along the repeat axis,
so every scan body is shape-homogeneous (fast compiles, small HLO).

Layer kinds:
  ``attn``    full-attention transformer block
  ``local``   sliding-window attention block
  ``rec``     RG-LRU recurrent block (recurrentgemma)
  ``rwkv``    RWKV-6 time/channel mixing block
  ``enc``     whisper encoder block (full self-attn, no causal mask)
  ``dec``     whisper decoder block (causal self-attn + cross-attn)

FFN kinds: ``swiglu`` | ``geglu`` | ``gelu`` | ``moe`` | ``rwkv_cmix``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class LayerGroup:
    """``repeat`` consecutive copies of the ``pattern`` of layer kinds."""

    pattern: Tuple[str, ...]
    repeat: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeat


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    groups: Tuple[LayerGroup, ...]
    head_dim: Optional[int] = None   # default d_model // n_heads
    ffn_kind: str = "swiglu"
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_window: Optional[int] = None      # for "local" layers
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0                # partial rotary (phi4)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: Optional[int] = None         # per-expert hidden dim
    capacity_factor: float = 1.25
    # recurrent mixers
    rwkv_head_dim: int = 64
    rglru_width: Optional[int] = None      # recurrent state width (default d_model)
    conv1d_width: int = 4
    # encoder-decoder (whisper)
    enc_seq: int = 0                       # frontend frames fed to the encoder
    enc_d_model: Optional[int] = None
    # embeddings / misc
    tie_embeddings: bool = True
    embed_scale: bool = False              # gemma-style sqrt(d) embedding scale
    norm_eps: float = 1e-6
    # frontend stub: "none" | "audio" (precomputed frames) | "patch" (vlm)
    frontend: str = "none"

    # ---- derived -----------------------------------------------------------
    @property
    def n_layers(self) -> int:
        return sum(g.n_layers for g in self.groups)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_enc_dec(self) -> bool:
        return any("dec" in g.pattern or "enc" in g.pattern for g in self.groups)

    @property
    def sub_quadratic(self) -> bool:
        """True iff no layer needs an unbounded-window attention KV cache."""
        for g in self.groups:
            for kind in g.pattern:
                if kind in ("attn", "enc", "dec"):
                    return False
        return True

    def layer_kinds(self) -> List[str]:
        out: List[str] = []
        for g in self.groups:
            out.extend(list(g.pattern) * g.repeat)
        return out

    # ---- parameter count (for roofline MODEL_FLOPS = 6·N·D) -----------------
    def param_count(self, *, active_only: bool = False) -> int:
        d, hd = self.d_model, self.hd
        nq, nkv = self.n_heads, self.n_kv_heads
        counts: Dict[str, int] = {}
        # per-kind per-layer params
        attn = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        if self.qkv_bias:
            attn += (nq + 2 * nkv) * hd
        if self.qk_norm:
            attn += 2 * hd
        dense_ffn = 3 * d * self.d_ff if self.ffn_kind in ("swiglu", "geglu") else 2 * d * self.d_ff
        eff = self.moe_d_ff or self.d_ff
        n_e = self.top_k if active_only else self.n_experts
        moe_ffn = 3 * d * eff * max(n_e, 1) + d * self.n_experts  # experts + router
        rwkv_tmix = 6 * d * d + 6 * d  # r,k,v,g,w,o projections + decay params (approx)
        rwkv_cmix = 2 * d * int(self.d_ff)
        w = self.rglru_width or d
        rglru = d * w * 2 + w * self.conv1d_width + 2 * w + w * d  # in/gate, conv, Λ/gates, out
        norms = 2 * d
        kind_params = {
            "attn": attn + (moe_ffn if self.ffn_kind == "moe" else dense_ffn) + norms,
            "local": attn + (moe_ffn if self.ffn_kind == "moe" else dense_ffn) + norms,
            "enc": attn + dense_ffn + norms,
            "dec": 2 * attn + dense_ffn + 3 * d,  # self + cross attention
            "rwkv": rwkv_tmix + rwkv_cmix + norms,
            "rec": rglru + dense_ffn + norms,
        }
        total = 0
        for kind in self.layer_kinds():
            total += kind_params[kind]
        total += self.vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab * d
        total += d  # final norm
        return int(total)


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what gets lowered and with which step fn."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A small same-family config for CPU smoke tests."""
    groups = []
    for g in cfg.groups:
        groups.append(LayerGroup(g.pattern, repeat=1))
    small = dict(
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        groups=tuple(groups[:2]) if len(groups) > 2 else tuple(groups),
        attn_window=min(cfg.attn_window, 32) if cfg.attn_window else None,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_d_ff=32 if cfg.moe_d_ff else None,
        # drop-free routing so smoke tests compare decode against prefill
        # exactly (capacity drops are order-dependent by design)
        capacity_factor=8.0 if cfg.n_experts else cfg.capacity_factor,
        rwkv_head_dim=16,
        rglru_width=64 if cfg.rglru_width else None,
        enc_seq=16 if cfg.enc_seq else 0,
        enc_d_model=64 if cfg.enc_d_model else None,
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


# ---------------------------------------------------------------------------
# Registry: populated by repro.configs.<arch> modules.
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        load_all_configs()
    if name not in _REGISTRY:
        load_all_configs()
    return _REGISTRY[name]


def all_configs() -> Dict[str, ModelConfig]:
    load_all_configs()
    return dict(_REGISTRY)


ARCH_NAMES = [
    "chameleon-34b", "gemma2-2b", "phi4-mini-3.8b", "qwen2-7b", "qwen3-4b",
    "rwkv6-3b", "mixtral-8x22b", "qwen3-moe-235b-a22b", "whisper-tiny",
    "recurrentgemma-9b",
]


def load_all_configs() -> None:
    import importlib

    for name in ARCH_NAMES:
        mod = name.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
