"""Partition plan: TP-alignment padding and sharding rules.

Real checkpoints have head counts and vocab sizes that do not divide the
production mesh's 16-way ``model`` axis (qwen2's 28 query / 4 KV heads,
whisper's 51865 vocab). The plan resolves this the way MaxText/vLLM do:

* query heads are zero-padded up to a multiple of TP (zero ``wq/wo`` slices
  contribute exactly nothing — the padded model is *functionally identical*,
  a property tested in ``tests/test_models.py``);
* KV heads are replicated up to TP when fewer (each replica serves the same
  query group — again exact);
* the vocab is zero-padded to a multiple of 128 and masked out in the loss.

The *useful-FLOPs ratio* in the roofline table (MODEL_FLOPS / HLO_FLOPs)
keeps this padding honest.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

from .config import ModelConfig


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclass(frozen=True)
class PartitionPlan:
    """Mesh-derived padding/replication decisions for one model."""

    tp: int = 1                  # size of the "model" mesh axis
    vocab_align: int = 128

    def eff_heads(self, cfg: ModelConfig) -> int:
        return _round_up(cfg.n_heads, self.tp)

    def eff_kv_heads(self, cfg: ModelConfig) -> int:
        """TP-aligned KV head count, chosen so replication stays *exact*.

        Exactness requires the padded model's query-group mapping
        ``i // G_new`` to address a replica of the original head
        ``i // G_orig``. Consecutive replication by ``rep`` is exact iff
        ``rep`` divides ``G_orig`` and no query padding is needed;
        otherwise we fall back to one KV head per query head (G_new = 1),
        which is always exact at the cost of a fatter KV cache (the
        roofline table carries that cost honestly).
        """
        kv, h, tp = cfg.n_kv_heads, cfg.n_heads, self.tp
        if kv % tp == 0:
            return kv
        g_orig = h // kv
        rep = _round_up(kv, tp) // kv
        if h % tp == 0 and g_orig % rep == 0:
            return kv * rep                      # consecutive replication
        return self.eff_heads(cfg)               # per-query KV (G_new = 1)

    def kv_replication(self, cfg: ModelConfig) -> int:
        return self.eff_kv_heads(cfg) // cfg.n_kv_heads

    def kv_graft_map(self, cfg: ModelConfig):
        """For checkpoint loading/tests: ``map[j]`` = original kv head index
        whose weights fill padded slot ``j`` (None = zero slot for padded
        query heads)."""
        kv = cfg.n_kv_heads
        h = cfg.n_heads
        eff_kv = self.eff_kv_heads(cfg)
        g_orig = h // kv
        if eff_kv == kv:
            return list(range(kv))
        if eff_kv == self.eff_heads(cfg):        # per-query KV
            return [i // g_orig if i < h else None for i in range(eff_kv)]
        rep = eff_kv // kv                       # consecutive replication
        return [j // rep for j in range(eff_kv)]

    def eff_vocab(self, cfg: ModelConfig) -> int:
        return _round_up(cfg.vocab, max(self.vocab_align, self.tp))

    def eff_rwkv_heads(self, cfg: ModelConfig) -> int:
        h = cfg.d_model // cfg.rwkv_head_dim
        return _round_up(h, self.tp)

    def check(self, cfg: ModelConfig) -> None:
        assert cfg.d_model % self.tp == 0, (cfg.name, "d_model % tp")
        assert cfg.d_ff % self.tp == 0, (cfg.name, "d_ff % tp")
        if cfg.moe_d_ff:
            assert cfg.moe_d_ff % self.tp == 0


IDENTITY_PLAN = PartitionPlan(tp=1, vocab_align=1)
