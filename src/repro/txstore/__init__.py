from .store import StateCell, VersionedStateStore
__all__ = ["StateCell", "VersionedStateStore"]
