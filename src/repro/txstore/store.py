"""Transactional versioned training-state store (DESIGN.md §2.1).

The control plane of the training runtime, synchronized by **OptSVA-CF**
(``repro.core``). Cluster state — parameters, optimizer state, the data
cursor, checkpoint metadata — lives in shared objects homed on registry
nodes; every actor runs transactions against them:

* the **trainer** commits each step(-group) as an *update* transaction with
  suprema 1 per object (one ``set`` per step);
* the **checkpointer** is an *irrevocable read-only* transaction: per paper
  §2.7 the snapshot is taken by the executor thread the moment the access
  condition passes and the objects are released immediately — the trainer
  blocks only for the buffer copy, never for the checkpoint I/O; and per
  §2.4 irrevocability means the file write can never be re-executed by a
  cascade;
* **evaluators** are read-only transactions (same asynchronous buffering);
* **elastic rescale** events are update transactions that swap shardings.

The paper's guarantees carry over directly: no torn reads (a checkpoint
snapshot is a consistent version cut across params/opt/cursor), no
writer starvation, deadlock freedom, and crashed actors roll back via the
transaction monitor (§3.4).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Iterable, List, Optional

from repro.core import (Mode, Registry, SharedObject, Transaction,
                        TransactionMonitor, access)


class StateCell:
    """A shared object holding one piece of cluster state.

    ``set`` is a pure WRITE (never reads), so trainer commits go through the
    log buffer without synchronizing with concurrent snapshot readers until
    apply time (§2.6). jax arrays are immutable, so snapshot copies are
    reference copies — cheap.
    """

    def __init__(self, value: Any = None, version: int = 0):
        self.value = value
        self.version = version

    @access(Mode.READ)
    def get(self):
        return self.value

    @access(Mode.READ)
    def get_version(self) -> int:
        return self.version

    @access(Mode.WRITE)
    def set(self, value, version: int) -> None:
        self.value = value
        self.version = version

    @access(Mode.UPDATE)
    def bump(self, fn: Callable[[Any], Any]) -> Any:
        self.value = fn(self.value)
        self.version += 1
        return self.value

    def __deepcopy__(self, memo):
        # jax arrays are immutable: snapshot = reference copy of the pytree
        return StateCell(self.value, self.version)

    def __tx_snapshot__(self) -> "StateCell":
        # Snapshot protocol (buffers.py): same reference-copy rationale, but
        # O(1) with no deepcopy dispatch on the checkpoint/read-buffer path.
        return StateCell(self.value, self.version)


class VersionedStateStore:
    """Named state cells + transaction factories for the runtime actors."""

    CELLS = ("params", "opt", "data_cursor", "ckpt_meta")

    def __init__(self, *, monitor_timeout: float = 30.0):
        self.registry = Registry()
        self.node = self.registry.add_node("trainer-host")
        self.cells: Dict[str, SharedObject] = {}
        for name in self.CELLS:
            self.cells[name] = self.registry.bind(
                name, StateCell(), node=self.node)
        self.monitor = TransactionMonitor(self.registry,
                                          timeout=monitor_timeout)
        self.monitor.start()

    def shutdown(self) -> None:
        self.monitor.stop()
        self.registry.shutdown()

    # ------------------------------------------------------------------ #
    # Actor transactions                                                  #
    # ------------------------------------------------------------------ #
    def commit_step(self, params, opt, step: int) -> None:
        """Trainer: publish the post-step state (one write per cell)."""
        t = Transaction(self.registry)
        p = t.writes(self.cells["params"], 1)
        o = t.writes(self.cells["opt"], 1)
        c = t.writes(self.cells["data_cursor"], 1)

        def body(t):
            p.set(params, step)
            o.set(opt, step)
            c.set(step, step)

        t.start(body)

    def snapshot(self, cells: Iterable[str] = ("params", "opt", "data_cursor"),
                 *, irrevocable: bool = True) -> Dict[str, Any]:
        """Checkpointer/evaluator: consistent read-only snapshot.

        Uses the §2.7 asynchronous buffering path: each cell is snapshotted
        and released by the executor as soon as its access condition passes.
        """
        t = Transaction(self.registry, irrevocable=irrevocable)
        proxies = {name: t.reads(self.cells[name], 2) for name in cells}
        out: Dict[str, Any] = {}

        def body(t):
            for name, proxy in proxies.items():
                out[name] = proxy.get()
                out[f"{name}_version"] = proxy.get_version()

        t.start(body)
        return out

    def record_checkpoint(self, step: int, path: str) -> None:
        t = Transaction(self.registry)
        m = t.writes(self.cells["ckpt_meta"], 1)
        t.start(lambda _t: m.set({"step": step, "path": path,
                                  "time": time.time()}, step))

    def latest_checkpoint(self) -> Optional[Dict[str, Any]]:
        snap = self.snapshot(("ckpt_meta",))
        return snap["ckpt_meta"]

    def rescale(self, remap: Callable[[Any], Any]) -> None:
        """Elastic event: atomically re-shard params+opt under one txn."""
        t = Transaction(self.registry)
        p = t.updates(self.cells["params"], 1)
        o = t.updates(self.cells["opt"], 1)

        def body(t):
            p.bump(remap)
            o.bump(remap)

        t.start(body)
