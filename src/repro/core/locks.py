"""Lock-based distributed concurrency-control baselines (paper §4.1).

* ``Mutex``  — one mutual-exclusion lock per shared object.
* ``R/W``    — one reader-writer lock per shared object (writer-preferring,
  so writers are not starved under read-heavy Eigenbench mixes).
* ``S2PL``   — conservative strong strict two-phase locking: every lock in
  the access set is acquired (in global order, to avoid deadlock) at start
  and held to commit. Satisfies opacity.
* ``2PL``    — non-strict two-phase locking: same acquisition, but the
  programmer releases each lock after the *last* access to its object
  (``LockTransaction.done(obj)``), which satisfies last-use opacity under
  correct last-access marking.
* ``GLock``  — a single global mutual-exclusion lock held for the entire
  transaction; the fully sequential baseline.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from .api import Mode, OpStats
from .registry import Node, Registry, SharedObject


class RWLock:
    """Writer-preferring reader-writer lock.

    ``acquire_*`` return True iff the caller actually blocked, so callers
    can report real waits (not mere acquisition counts) in their stats.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._readers_ok = threading.Condition(self._lock)
        self._writers_ok = threading.Condition(self._lock)
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0

    def acquire_read(self) -> bool:
        with self._lock:
            waited = False
            while self._writer or self._writers_waiting:
                waited = True
                self._readers_ok.wait()
            self._readers += 1
            return waited

    def release_read(self) -> None:
        with self._lock:
            self._readers -= 1
            if self._readers == 0:
                self._writers_ok.notify()

    def acquire_write(self) -> bool:
        with self._lock:
            self._writers_waiting += 1
            waited = False
            while self._writer or self._readers:
                waited = True
                self._writers_ok.wait()
            self._writers_waiting -= 1
            self._writer = True
            return waited

    def release_write(self) -> None:
        with self._lock:
            self._writer = False
            # Writer preference: hand off to a waiting writer if there is
            # one; only when no writer waits may readers be woken. Waking
            # both classes at once lets a reader slip in whenever it wins
            # the race to the monitor before the writer re-evaluates,
            # breaking the preference invariant under simultaneous wakeup.
            if self._writers_waiting:
                self._writers_ok.notify()
            else:
                self._readers_ok.notify_all()


class _LockTable:
    """Process-wide lock attachments for shared objects."""

    def __init__(self):
        self._lock = threading.Lock()
        self._mutex: Dict[SharedObject, threading.Lock] = {}
        self._rw: Dict[SharedObject, RWLock] = {}

    def mutex(self, shared: SharedObject) -> threading.Lock:
        with self._lock:
            return self._mutex.setdefault(shared, threading.Lock())

    def rw(self, shared: SharedObject) -> RWLock:
        with self._lock:
            return self._rw.setdefault(shared, RWLock())


LOCK_TABLE = _LockTable()
GLOBAL_LOCK = threading.Lock()


class _LockProxy:
    __slots__ = ("_txn", "_shared")

    def __init__(self, txn: "LockTransaction", shared: SharedObject):
        object.__setattr__(self, "_txn", txn)
        object.__setattr__(self, "_shared", shared)

    def __getattr__(self, method: str) -> Callable[..., Any]:
        txn = object.__getattribute__(self, "_txn")
        shared = object.__getattribute__(self, "_shared")

        def call(*args: Any, **kwargs: Any) -> Any:
            return txn._invoke(shared, method, args, kwargs)

        return call


class LockTransaction:
    """One transaction under a lock-based scheme.

    ``kind``: ``"mutex"`` | ``"rw"`` | ``"glock"``; ``strict=True`` keeps
    locks to commit (S2PL); ``strict=False`` enables ``done(obj)`` early
    release (2PL).
    """

    def __init__(self, registry: Optional[Registry] = None, *,
                 kind: str = "mutex", strict: bool = True,
                 client_node: Optional[Node] = None):
        assert kind in ("mutex", "rw", "glock")
        self.registry = registry
        self.kind = kind
        self.strict = strict
        self.client_node = client_node
        self.stats = OpStats()
        # (shared, will_write) in declaration order
        self._declared: List[Tuple[SharedObject, bool]] = []
        self._proxies: Dict[SharedObject, _LockProxy] = {}
        self._held: Dict[SharedObject, str] = {}  # shared -> "read"/"write"
        self._started = False
        self._terminated = False

    # -- preamble -------------------------------------------------------------
    def _declare(self, obj: Union[SharedObject, str], will_write: bool) -> _LockProxy:
        shared = self.registry.locate(obj) if isinstance(obj, str) else obj
        self._declared.append((shared, will_write))
        proxy = _LockProxy(self, shared)
        self._proxies[shared] = proxy
        return proxy

    def reads(self, obj, *_sup) -> _LockProxy:
        return self._declare(obj, will_write=False)

    def writes(self, obj, *_sup) -> _LockProxy:
        return self._declare(obj, will_write=True)

    def updates(self, obj, *_sup) -> _LockProxy:
        return self._declare(obj, will_write=True)

    def accesses(self, obj, *_sup) -> _LockProxy:
        return self._declare(obj, will_write=True)

    # -- lifecycle ------------------------------------------------------------
    def begin(self) -> None:
        if self._started:
            return
        self._started = True
        if self.kind == "glock":
            if not GLOBAL_LOCK.acquire(blocking=False):
                self.stats.waits += 1
                GLOBAL_LOCK.acquire()
            return
        # Deadlock avoidance: acquire in global header-uid order. A wait is
        # counted only when the lock was actually contended, so the
        # Eigenbench `waits` column is comparable across frameworks.
        for shared, will_write in sorted(self._declared, key=lambda p: p[0].header.uid):
            if self.kind == "mutex":
                m = LOCK_TABLE.mutex(shared)
                if not m.acquire(blocking=False):
                    self.stats.waits += 1
                    m.acquire()
                self._held[shared] = "write"
            else:
                if will_write:
                    if LOCK_TABLE.rw(shared).acquire_write():
                        self.stats.waits += 1
                    self._held[shared] = "write"
                else:
                    if LOCK_TABLE.rw(shared).acquire_read():
                        self.stats.waits += 1
                    self._held[shared] = "read"

    def _invoke(self, shared: SharedObject, method: str, args: tuple,
                kwargs: dict) -> Any:
        shared.check_reachable()
        mode = shared.mode_of(method)
        v = shared.raw_call(method, args, kwargs, from_node=self.client_node)
        if mode is Mode.READ:
            self.stats.reads += 1
        elif mode is Mode.WRITE:
            self.stats.writes += 1
        else:
            self.stats.updates += 1
        return v

    def done(self, proxy_or_shared: Union[_LockProxy, SharedObject]) -> None:
        """2PL early release: the programmer marks the last access (§4.1)."""
        if self.strict or self.kind == "glock":
            return
        shared = (proxy_or_shared if isinstance(proxy_or_shared, SharedObject)
                  else object.__getattribute__(proxy_or_shared, "_shared"))
        self._release_one(shared)

    def _release_one(self, shared: SharedObject) -> None:
        held = self._held.pop(shared, None)
        if held is None:
            return
        if self.kind == "mutex":
            LOCK_TABLE.mutex(shared).release()
        elif held == "write":
            LOCK_TABLE.rw(shared).release_write()
        else:
            LOCK_TABLE.rw(shared).release_read()

    def commit(self) -> None:
        if self._terminated:
            return
        if self.kind == "glock":
            GLOBAL_LOCK.release()
        else:
            for shared in list(self._held):
                self._release_one(shared)
        self._terminated = True

    # Locking solutions have no rollback; abort == release (used by tests only).
    abort = commit

    def start(self, body: Callable[["LockTransaction"], Any]) -> Any:
        self.begin()
        try:
            return body(self)
        finally:
            self.commit()
