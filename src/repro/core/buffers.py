"""Copy and log buffers for the complex-object model (paper §2.6).

* :class:`CopyBuffer` — a deep copy of the entire object state. Creating one
  requires the access condition (it views state); it then serves local reads
  after release, and the checkpoint variant (``st``) restores state on abort.

* :class:`LogBuffer` — records method invocations without touching the
  object's state, which is what lets *pure writes* execute with **no prior
  synchronization**. Applying the log replays the recorded calls against the
  real object ("if a method was not previously executed, it is executed on
  the original object at the time the log is being applied", §2.6).

Both buffer types live on the object's home node (CF model: side effects of
replay must occur where the object lives). In this in-process realization
that is automatic; the ``home_node`` tag is kept for the distributed
simulation and assertions.
"""
from __future__ import annotations

import copy
from typing import Any, Callable, List, Optional, Tuple


class CopyBuffer:
    """Full-state snapshot of a shared object."""

    __slots__ = ("state", "instance", "home_node")

    def __init__(self, obj: Any, instance: int, home_node: Optional[object] = None):
        self.state = copy.deepcopy(obj)
        self.instance = instance          # instance epoch observed at snapshot time
        self.home_node = home_node

    def call(self, method: str, args: tuple, kwargs: dict) -> Any:
        """Execute ``method`` against the buffered copy (local read path)."""
        return getattr(self.state, method)(*args, **kwargs)

    def restore_into(self, target_holder: "StateHolder") -> None:
        """Abort path: replace the live object state with the snapshot."""
        target_holder.obj = copy.deepcopy(self.state)


class LogBuffer:
    """Method-invocation log for unsynchronized pure writes."""

    __slots__ = ("entries", "home_node")

    def __init__(self, home_node: Optional[object] = None):
        self.entries: List[Tuple[str, tuple, dict]] = []
        self.home_node = home_node

    def __len__(self) -> int:
        return len(self.entries)

    def record(self, method: str, args: tuple, kwargs: dict) -> None:
        """Log a write call. Pure writes return no value, so recording is
        sufficient — the effects materialize at apply time."""
        self.entries.append((method, args, kwargs))

    def apply_to(self, obj: Any) -> None:
        """Replay the log against the real object, then clear it."""
        for method, args, kwargs in self.entries:
            getattr(obj, method)(*args, **kwargs)
        self.entries.clear()


class StateHolder:
    """Mutable cell holding the live state of a shared object.

    Restores swap the referenced object rather than mutating in place so a
    doomed transaction still holding the stale reference keeps reading the
    invalid instance it observed (matching the paper's "invalid instance"
    semantics) instead of silently seeing restored state.
    """

    __slots__ = ("obj",)

    def __init__(self, obj: Any):
        self.obj = obj
