"""Copy and log buffers for the complex-object model (paper §2.6).

* :class:`CopyBuffer` — a snapshot of the entire object state. Creating one
  requires the access condition (it views state); it then serves local reads
  after release, and the checkpoint variant (``st``) restores state on abort.

* :class:`LogBuffer` — records method invocations without touching the
  object's state, which is what lets *pure writes* execute with **no prior
  synchronization**. Applying the log replays the recorded calls against the
  real object ("if a method was not previously executed, it is executed on
  the original object at the time the log is being applied", §2.6).

Snapshot protocol (DESIGN.md §1.4): ``copy.deepcopy`` of the whole object on
every checkpoint/read-buffer is the dominant per-operation cost for small
objects. An object class may therefore implement

* ``__tx_snapshot__() -> obj`` — return an independent object exposing the
  same methods, capturing the current state (O(1)/shallow where the state
  is immutable or a flat cell);
* ``__tx_restore__() -> obj`` — called on a *snapshot*, return a fresh live
  object carrying the snapshot's state (defaults to ``__tx_snapshot__`` —
  for most classes "snapshot of a snapshot" is exactly a restore).

``copy.deepcopy`` remains the fallback, so arbitrary objects keep working.
Restores swap a *new* object into the holder either way, preserving the
invalid-instance semantics (a doomed transaction still holding the stale
reference keeps reading the instance it observed).

Both buffer types live on the object's home node (CF model: side effects of
replay must occur where the object lives). In this in-process realization
that is automatic; the ``home_node`` tag is kept for the distributed
simulation and assertions.
"""
from __future__ import annotations

import copy
from typing import Any, List, Optional, Tuple


def snapshot_state(obj: Any) -> Any:
    """Snapshot ``obj`` via ``__tx_snapshot__`` or deepcopy fallback."""
    fn = getattr(obj, "__tx_snapshot__", None)
    if fn is not None:
        return fn()
    return copy.deepcopy(obj)


def restore_state(snap: Any) -> Any:
    """Materialize a fresh live object from a snapshot."""
    fn = getattr(snap, "__tx_restore__", None)
    if fn is None:
        fn = getattr(snap, "__tx_snapshot__", None)
    if fn is not None:
        return fn()
    return copy.deepcopy(snap)


class CopyBuffer:
    """Full-state snapshot of a shared object."""

    __slots__ = ("state", "instance", "home_node")

    def __init__(self, obj: Any, instance: int, home_node: Optional[object] = None):
        self.state = snapshot_state(obj)
        self.instance = instance          # instance epoch observed at snapshot time
        self.home_node = home_node

    def call(self, method: str, args: tuple, kwargs: dict) -> Any:
        """Execute ``method`` against the buffered copy (local read path)."""
        return getattr(self.state, method)(*args, **kwargs)

    def restore_into(self, target_holder: "StateHolder") -> None:
        """Abort path: replace the live object state with the snapshot."""
        target_holder.obj = restore_state(self.state)


class LogBuffer:
    """Method-invocation log for unsynchronized pure writes."""

    __slots__ = ("entries", "home_node")

    def __init__(self, home_node: Optional[object] = None):
        self.entries: List[Tuple[str, tuple, dict]] = []
        self.home_node = home_node

    def __len__(self) -> int:
        return len(self.entries)

    def record(self, method: str, args: tuple, kwargs: dict) -> None:
        """Log a write call. Pure writes return no value, so recording is
        sufficient — the effects materialize at apply time."""
        self.entries.append((method, args, kwargs))

    def apply_to(self, obj: Any) -> None:
        """Replay the log against the real object, then clear it."""
        for method, args, kwargs in self.entries:
            getattr(obj, method)(*args, **kwargs)
        self.entries.clear()


class StateHolder:
    """Mutable cell holding the live state of a shared object.

    Restores swap the referenced object rather than mutating in place so a
    doomed transaction still holding the stale reference keeps reading the
    invalid instance it observed (matching the paper's "invalid instance"
    semantics) instead of silently seeing restored state.
    """

    __slots__ = ("obj",)

    def __init__(self, obj: Any):
        self.obj = obj
