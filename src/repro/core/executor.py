"""The executor thread (paper §3.3).

OptSVA-CF calls for asynchronous tasks (read-only snapshotting, last-write
log application). Spawning a thread per task is costly, so — exactly as in
Atomic RMI 2 — each node runs one always-on executor thread to which
transactions hand *tasks*: a ``condition`` plus ``code``. The executor runs
the code only once the condition holds, re-evaluating whenever any version
counter (``lv``/``ltv``) that can influence a condition changes.

Task code never blocks (its only precondition IS the condition), so a single
thread cannot deadlock; it can, however, become a throughput bottleneck under
heavy asynchrony — the paper observes the same in §4.3, and ``workers > 1``
is provided to explore beyond it (a beyond-paper knob; default stays 1,
faithful).
"""
from __future__ import annotations

import threading
import traceback
from collections import deque
from typing import Callable, List, Optional

from .api import TransactionError


class Task:
    """A unit of deferred work gated on a version-counter condition."""

    __slots__ = ("condition", "code", "done", "error", "name")

    def __init__(self, condition: Callable[[], bool], code: Callable[[], None],
                 name: str = "task"):
        self.condition = condition
        self.code = code
        self.done = threading.Event()
        self.error: Optional[BaseException] = None
        self.name = name

    def join(self) -> None:
        """Wait for completion; re-raise transactional errors in the caller."""
        self.done.wait()
        if self.error is not None:
            if isinstance(self.error, TransactionError):
                raise self.error
            raise RuntimeError(f"executor task {self.name} failed") from self.error

    def run_if_ready(self) -> bool:
        if not self.condition():
            return False
        try:
            self.code()
        except BaseException as e:  # noqa: BLE001 - propagate via join()
            self.error = e
            if not isinstance(e, TransactionError):  # pragma: no cover
                traceback.print_exc()
        finally:
            self.done.set()
        return True


class Executor:
    """Per-node executor: queue of condition-gated tasks + wakeup signal."""

    def __init__(self, name: str = "executor", workers: int = 1):
        self._lock = threading.Lock()
        self._wakeup = threading.Condition(self._lock)
        self._pending: deque[Task] = deque()
        self._stopping = False
        self._threads: List[threading.Thread] = []
        for i in range(max(1, workers)):
            t = threading.Thread(target=self._loop, name=f"{name}-{i}", daemon=True)
            t.start()
            self._threads.append(t)

    # Called by VersionHeader listeners on every lv/ltv/instance change.
    def poke(self) -> None:
        with self._lock:
            self._wakeup.notify_all()

    def submit(self, condition: Callable[[], bool], code: Callable[[], None],
               name: str = "task") -> Task:
        task = Task(condition, code, name)
        with self._lock:
            if self._stopping:
                raise RuntimeError("executor is shut down")
            self._pending.append(task)
            self._wakeup.notify_all()
        return task

    def _loop(self) -> None:
        while True:
            with self._lock:
                if self._stopping and not self._pending:
                    return
                task: Optional[Task] = None
                # Scan for a ready task; preserve FIFO among non-ready ones.
                for _ in range(len(self._pending)):
                    cand = self._pending.popleft()
                    try:
                        ready = cand.condition()
                    except BaseException as e:  # noqa: BLE001
                        cand.error = e
                        cand.done.set()
                        continue
                    if ready:
                        task = cand
                        break
                    self._pending.append(cand)
                if task is None:
                    if self._stopping:
                        return
                    # Counter changes poke us; timeout is a liveness backstop.
                    self._wakeup.wait(timeout=0.05)
                    continue
            task.run_if_ready()

    def shutdown(self) -> None:
        with self._lock:
            self._stopping = True
            self._wakeup.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)

    def pending_count(self) -> int:
        with self._lock:
            return len(self._pending)
