"""The executor thread (paper §3.3) — event-driven (DESIGN.md §1.3).

OptSVA-CF calls for asynchronous tasks (read-only snapshotting, last-write
log application). Spawning a thread per task is costly, so — exactly as in
Atomic RMI 2 — each node runs one always-on executor thread to which
transactions hand *tasks*. A task is gated on one version-counter condition
of one :class:`~repro.core.versioning.VersionHeader`: ``(header, kind, pv)``
with ``kind`` either ``"access"`` (``lv >= pv - 1``) or ``"termination"``
(``ltv >= pv - 1``).

Dispatch is O(woken tasks), with no scan and no timed polling: ``submit``
parks the task directly on the header's waiter queue; when the counter
reaches the threshold the header's drain enqueues the task on this
executor's ready-queue and the worker thread runs it **unconditionally** —
the gating conditions are monotonic, so a task woken by its header can
never become un-ready again (this also closes the seed's task-loss hazard,
where a ready task re-checked outside the lock could be silently dropped).
Per task the condition is evaluated at most twice: once at submit (already
satisfied → straight to the ready-queue) and once as the heap-top
comparison that wakes it.

Task code never blocks (its only precondition IS the condition), so a single
thread cannot deadlock; it can, however, become a throughput bottleneck under
heavy asynchrony — the paper observes the same in §4.3, and ``workers > 1``
is provided to explore beyond it (a beyond-paper knob; default stays 1,
faithful).
"""
from __future__ import annotations

import threading
import traceback
from collections import deque
from typing import List, Optional, Callable

from .api import TransactionError
from .versioning import VersionHeader


class Task:
    """A unit of deferred work gated on a version-counter condition."""

    __slots__ = ("code", "done", "error", "name")

    def __init__(self, code: Callable[[], None], name: str = "task"):
        self.code = code
        self.done = threading.Event()
        self.error: Optional[BaseException] = None
        self.name = name

    def join(self) -> None:
        """Wait for completion; re-raise transactional errors in the caller."""
        self.done.wait()
        if self.error is not None:
            if isinstance(self.error, TransactionError):
                raise self.error
            raise RuntimeError(f"executor task {self.name} failed") from self.error

    def run(self) -> None:
        """Execute unconditionally: the gating condition held when this task
        was enqueued, and monotonicity means it still holds."""
        try:
            self.code()
        except BaseException as e:  # noqa: BLE001 - propagate via join()
            self.error = e
            if not isinstance(e, TransactionError):  # pragma: no cover
                traceback.print_exc()
        finally:
            self.done.set()


_wake_tl = threading.local()


def defer_wake_inline() -> None:
    """Mark the calling thread latency-critical (e.g. a connection reader
    multiplexing many conversations): ``wake_inline`` tasks woken by a
    counter advance on this thread are enqueued to the executor instead of
    running on it, so foreign transactions' service time never stalls it."""
    _wake_tl.defer = True


def _run_trampolined(task: Task) -> None:
    """Run a woken task on the current thread, flattening cascades: if a
    task's release wakes further ``wake_inline`` tasks, they queue on this
    thread-local deque and run iteratively after it — depth-first order,
    constant stack depth."""
    pending = getattr(_wake_tl, "pending", None)
    if pending is not None:          # already inside a cascade: defer
        pending.append(task)
        return
    _wake_tl.pending = pending = deque((task,))
    try:
        while pending:
            pending.popleft().run()
    finally:
        _wake_tl.pending = None


class Executor:
    """Per-node executor consuming a ready-queue fed by header callbacks."""

    def __init__(self, name: str = "executor", workers: int = 1,
                 inline_ready: bool = True):
        self._lock = threading.Lock()
        self._wakeup = threading.Condition(self._lock)
        self._ready: deque[Task] = deque()
        self._inline_ready = inline_ready
        self._stopping = False
        self._dead = False                 # workers joined; nothing drains
        self._threads: List[threading.Thread] = []
        for i in range(max(1, workers)):
            t = threading.Thread(target=self._loop, name=f"{name}-{i}", daemon=True)
            t.start()
            self._threads.append(t)

    def _enqueue(self, task: Task) -> None:
        with self._lock:
            if self._dead:
                # Workers are gone: fail the task instead of parking it on a
                # queue nobody drains (join() would hang forever).
                task.error = RuntimeError("executor is shut down")
                task.done.set()
                return
            self._ready.append(task)
            self._wakeup.notify()

    def submit(self, header: VersionHeader, kind: str, pv: int,
               code: Callable[[], None], name: str = "task",
               inline_ready: Optional[bool] = None,
               wake_inline: bool = False) -> Task:
        """Submit ``code`` gated on ``(header, kind, pv)``.

        If the condition is not yet satisfied the task parks on the header's
        waiter queue and the releasing transaction's drain enqueues it on the
        ready-queue. If it already holds, the task runs *inline* on the
        submitting thread: the work (snapshot / log apply) must complete
        before the object can be released anyway, and two context switches
        through the executor thread are pure scheduling overhead — the
        asynchrony of §2.7/§2.8.4 buys overlap only while the gate is
        closed. (``inline_ready=False`` restores strict asynchrony.)

        ``inline_ready`` overrides the executor-wide default per call (the
        node server decides per call site: one-way kickoffs arriving on a
        connection reader defer ready tasks to the executor, while a
        dispense handler — pool worker, or reader on its uncontended fast
        path, where the work is a bounded state snapshot — runs them
        inline so the result rides back on the dispense reply).

        ``wake_inline=True`` additionally runs a *parked* task directly on
        the thread whose counter advance opened its gate, instead of
        bouncing it through the ready-queue — one fewer context switch on
        every contended wakeup. Task code never blocks (its only
        precondition IS the gate), so this cannot deadlock; a release
        cascade (a woken task whose own release wakes the next) is
        flattened by a per-thread trampoline, so arbitrarily long waiter
        chains run iteratively, never recursively."""
        with self._lock:
            if self._stopping:
                raise RuntimeError("executor is shut down")
        task = Task(code, name)
        inline = self._inline_ready if inline_ready is None else inline_ready
        if wake_inline:
            def on_wake() -> None:
                if getattr(_wake_tl, "defer", False):
                    self._enqueue(task)   # latency-critical waker thread
                else:
                    _run_trampolined(task)
        else:
            def on_wake() -> None:
                self._enqueue(task)
        if not header.park(kind, pv, on_wake):
            if inline:
                task.run()
            else:
                self._enqueue(task)
        return task

    def _loop(self) -> None:
        while True:
            with self._lock:
                while not self._ready:
                    if self._stopping:
                        return
                    self._wakeup.wait()
                task = self._ready.popleft()
            task.run()

    def shutdown(self) -> None:
        with self._lock:
            self._stopping = True
            self._wakeup.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)
        # A header callback racing shutdown may have enqueued after the
        # workers exited; fail those tasks so joiners unblock.
        with self._lock:
            self._dead = True
            leftovers = list(self._ready)
            self._ready.clear()
        for task in leftovers:
            task.error = RuntimeError("executor is shut down")
            task.done.set()

    def pending_count(self) -> int:
        """Tasks sitting in the ready-queue (parked tasks live on headers)."""
        with self._lock:
            return len(self._ready)
