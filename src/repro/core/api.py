"""Public API surface of the OptSVA-CF core (paper Figs. 7-9).

Users annotate shared-object methods with an access mode, publish the
object in a :class:`~repro.core.registry.Registry`, and run transactions
through :class:`~repro.core.transaction.Transaction`.
"""
from __future__ import annotations

import enum
import math
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

INF = math.inf

_WARNED: set = set()


def warn_deprecated(key: str, msg: str) -> None:
    """Emit ``msg`` as a DeprecationWarning exactly once per ``key`` per
    process (the API-migration contract: legacy forms keep working but
    say so exactly once)."""
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(msg, DeprecationWarning, stacklevel=3)


class Mode(enum.Enum):
    """Operation classification of the complex-object model (paper §2.5)."""

    READ = "read"      # may view state / return value; never modifies state
    WRITE = "write"    # may modify state; never views it
    UPDATE = "update"  # may both view and modify state


def access(mode: Mode, commutes: Optional[str] = None) -> Callable:
    """Method decorator declaring the access mode of a shared-object method.

    Mirrors the ``@Access(Mode.READ)`` annotation of Atomic RMI 2 (Fig. 7)::

        class Account:
            @access(Mode.READ)
            def balance(self): ...

    ``commutes`` names a *commuting method class* (DESIGN.md §12): every
    method sharing the same class label commutes with every other (including
    itself), so invocations restricted to one class may skip version-gated
    dispensing and merge as deltas at the home node. Only ``Mode.WRITE``
    methods may commute — a commuting operation must never view state, or
    the merge order would be observable.
    """

    def deco(fn: Callable) -> Callable:
        fn.__access_mode__ = mode
        if commutes is not None:
            if mode is not Mode.WRITE:
                raise TypeError(
                    f"commutes={commutes!r} requires Mode.WRITE: a commuting "
                    f"method must be write-only (got {mode})")
            fn.__access_commutes__ = commutes
        return fn

    return deco


#: Per-class cache of {method name: (Mode, commute class | None)}, built
#: once on first access instead of re-resolving ``getattr(type(obj), name)``
#: in the hot dispatch path. Unannotated methods are simply absent.
_CLASS_ACCESS_MAPS: Dict[type, Dict[str, tuple]] = {}


def class_access_map(cls: type) -> Dict[str, tuple]:
    """The cached ``{name: (mode, commute_class)}`` map of ``cls``."""
    m = _CLASS_ACCESS_MAPS.get(cls)
    if m is None:
        m = {}
        for name in dir(cls):
            fn = getattr(cls, name, None)
            mode = getattr(fn, "__access_mode__", None)
            if mode is not None:
                m[name] = (mode, getattr(fn, "__access_commutes__", None))
        _CLASS_ACCESS_MAPS[cls] = m
    return m


def method_mode(obj: Any, name: str) -> Mode:
    """Resolve the declared access mode of ``obj.name``.

    Raises ``TypeError`` for unannotated methods: in the CF model every
    remotely callable operation must be classified (paper §2.5).
    """
    ent = class_access_map(type(obj)).get(name)
    if ent is None:
        if getattr(type(obj), name, None) is None:
            raise AttributeError(
                f"{type(obj).__name__} has no method {name!r}")
        raise TypeError(
            f"method {type(obj).__name__}.{name} lacks an @access(Mode.*) annotation"
        )
    return ent[0]


def method_commutes(obj: Any, name: str) -> Optional[str]:
    """The commute-class label of ``obj.name``, or ``None``."""
    ent = class_access_map(type(obj)).get(name)
    return ent[1] if ent is not None else None


def commute_classes(obj: Any) -> Dict[str, str]:
    """All declared ``{method name: commute class}`` entries of ``obj``."""
    return {n: c for n, (m, c) in class_access_map(type(obj)).items()
            if c is not None}


@dataclass
class Suprema:
    """A-priori upper bounds on per-object access counts (paper §2.2).

    ``inf`` means "unknown"; the algorithm stays correct but releases the
    object only at commit/abort instead of early.

    ``commutes`` marks a *commute-restricted* declaration (DESIGN.md §12):
    the transaction promises to touch the object only through methods of
    the named commuting class. Such accesses are write-only (``writes``
    bounds them) and may skip version-gated dispensing entirely.
    """

    reads: float = INF
    writes: float = INF
    updates: float = INF
    commutes: Optional[str] = None

    @property
    def total(self) -> float:
        return self.reads + self.writes + self.updates

    @property
    def read_only(self) -> bool:
        """True iff the transaction may only ever read this object."""
        return self.writes == 0 and self.updates == 0

    def validate(self) -> None:
        for v, n in ((self.reads, "reads"), (self.writes, "writes"), (self.updates, "updates")):
            if v != INF and (v < 0 or int(v) != v):
                raise ValueError(f"supremum {n} must be a non-negative integer or inf, got {v}")


class TransactionError(RuntimeError):
    """Base class for transactional control-flow errors."""


class AbortError(TransactionError):
    """The transaction aborted (manually, by cascade, or forced)."""

    def __init__(self, msg: str, *, forced: bool = False):
        super().__init__(msg)
        self.forced = forced


class SupremumViolation(AbortError):
    """An object was accessed more times than its declared supremum (paper §2.2)."""

    def __init__(self, msg: str):
        super().__init__(msg, forced=True)


class RetrySignal(TransactionError):
    """Raised by ``Transaction.retry()``; caught by ``start`` to re-run the atomic block."""


class RemoteObjectFailure(TransactionError):
    """Crash-stop remote object failure (paper §3.4)."""


class InstanceInvalidated(TransactionError):
    """A home node reports that an observed object instance was invalidated.

    Raised by the network transport when a server-side session operation
    finds the object's instance epoch has moved past the one the session
    observed (a cascading abort restored older state, §2.3). The client
    transaction maps this onto its forced-abort path — the in-process
    transport discovers the same condition via ``_validity_check`` instead.
    """


class IllegalState(TransactionError):
    """API misuse (e.g. operating on a finished transaction)."""


@dataclass
class OpStats:
    """Per-transaction operation statistics (used by benchmarks and tests)."""

    reads: int = 0
    writes: int = 0
    updates: int = 0
    waits: int = 0
    aborts: int = 0
    retries: int = 0
    extra: Dict[str, Any] = field(default_factory=dict)

    @property
    def ops(self) -> int:
        return self.reads + self.writes + self.updates
