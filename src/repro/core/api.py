"""Public API surface of the OptSVA-CF core (paper Figs. 7-9).

Users annotate shared-object methods with an access mode, publish the
object in a :class:`~repro.core.registry.Registry`, and run transactions
through :class:`~repro.core.transaction.Transaction`.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

INF = math.inf


class Mode(enum.Enum):
    """Operation classification of the complex-object model (paper §2.5)."""

    READ = "read"      # may view state / return value; never modifies state
    WRITE = "write"    # may modify state; never views it
    UPDATE = "update"  # may both view and modify state


def access(mode: Mode) -> Callable:
    """Method decorator declaring the access mode of a shared-object method.

    Mirrors the ``@Access(Mode.READ)`` annotation of Atomic RMI 2 (Fig. 7)::

        class Account:
            @access(Mode.READ)
            def balance(self): ...
    """

    def deco(fn: Callable) -> Callable:
        fn.__access_mode__ = mode
        return fn

    return deco


def method_mode(obj: Any, name: str) -> Mode:
    """Resolve the declared access mode of ``obj.name``.

    Raises ``TypeError`` for unannotated methods: in the CF model every
    remotely callable operation must be classified (paper §2.5).
    """
    fn = getattr(type(obj), name, None)
    if fn is None:
        raise AttributeError(f"{type(obj).__name__} has no method {name!r}")
    mode = getattr(fn, "__access_mode__", None)
    if mode is None:
        raise TypeError(
            f"method {type(obj).__name__}.{name} lacks an @access(Mode.*) annotation"
        )
    return mode


@dataclass
class Suprema:
    """A-priori upper bounds on per-object access counts (paper §2.2).

    ``inf`` means "unknown"; the algorithm stays correct but releases the
    object only at commit/abort instead of early.
    """

    reads: float = INF
    writes: float = INF
    updates: float = INF

    @property
    def total(self) -> float:
        return self.reads + self.writes + self.updates

    @property
    def read_only(self) -> bool:
        """True iff the transaction may only ever read this object."""
        return self.writes == 0 and self.updates == 0

    def validate(self) -> None:
        for v, n in ((self.reads, "reads"), (self.writes, "writes"), (self.updates, "updates")):
            if v != INF and (v < 0 or int(v) != v):
                raise ValueError(f"supremum {n} must be a non-negative integer or inf, got {v}")


class TransactionError(RuntimeError):
    """Base class for transactional control-flow errors."""


class AbortError(TransactionError):
    """The transaction aborted (manually, by cascade, or forced)."""

    def __init__(self, msg: str, *, forced: bool = False):
        super().__init__(msg)
        self.forced = forced


class SupremumViolation(AbortError):
    """An object was accessed more times than its declared supremum (paper §2.2)."""

    def __init__(self, msg: str):
        super().__init__(msg, forced=True)


class RetrySignal(TransactionError):
    """Raised by ``Transaction.retry()``; caught by ``start`` to re-run the atomic block."""


class RemoteObjectFailure(TransactionError):
    """Crash-stop remote object failure (paper §3.4)."""


class InstanceInvalidated(TransactionError):
    """A home node reports that an observed object instance was invalidated.

    Raised by the network transport when a server-side session operation
    finds the object's instance epoch has moved past the one the session
    observed (a cascading abort restored older state, §2.3). The client
    transaction maps this onto its forced-abort path — the in-process
    transport discovers the same condition via ``_validity_check`` instead.
    """


class IllegalState(TransactionError):
    """API misuse (e.g. operating on a finished transaction)."""


@dataclass
class OpStats:
    """Per-transaction operation statistics (used by benchmarks and tests)."""

    reads: int = 0
    writes: int = 0
    updates: int = 0
    waits: int = 0
    aborts: int = 0
    retries: int = 0
    extra: Dict[str, Any] = field(default_factory=dict)

    @property
    def ops(self) -> int:
        return self.reads + self.writes + self.updates
