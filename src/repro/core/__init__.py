"""OptSVA-CF pessimistic distributed transactional memory (the paper's core).

Public surface::

    from repro.core import (
        Mode, access, Suprema, Registry, Transaction,
        SvaTransaction, LockTransaction, TfaTransaction,
        AbortError, RetrySignal, TransactionMonitor,
    )
"""
from .api import (
    INF, AbortError, IllegalState, Mode, OpStats, RemoteObjectFailure,
    RetrySignal, Suprema, SupremumViolation, TransactionError, access,
)
from .buffers import CopyBuffer, LogBuffer, StateHolder
from .executor import Executor, Task
from .faults import TransactionMonitor
from .locks import GLOBAL_LOCK, LockTransaction, RWLock
from .registry import Node, Registry, SharedObject
from .sva import SvaTransaction
from .tfa import TfaTransaction
from .transaction import CommuteAccess, ObjectAccess, Transaction, TxProxy
from .versioning import VersionHeader, dispense_versions

__all__ = [
    "INF", "AbortError", "IllegalState", "Mode", "OpStats",
    "RemoteObjectFailure", "RetrySignal", "Suprema", "SupremumViolation",
    "TransactionError", "access", "CopyBuffer", "LogBuffer", "StateHolder",
    "Executor", "Task", "TransactionMonitor", "GLOBAL_LOCK",
    "LockTransaction", "RWLock", "Node", "Registry", "SharedObject",
    "SvaTransaction", "TfaTransaction", "CommuteAccess", "ObjectAccess",
    "Transaction", "TxProxy", "VersionHeader", "dispense_versions",
]
