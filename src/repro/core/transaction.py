"""OptSVA-CF transactions (paper §2.8, API per Figs. 8-9).

The transaction life cycle:

1. *Preamble* — the client declares its access set with ``reads`` /
   ``writes`` / ``updates`` / ``accesses``, optionally with suprema.
2. *Start* — private versions are dispensed atomically for the whole access
   set (global-order version-lock acquisition, §2.10.2); for every
   *read-only* object an asynchronous buffering task is enqueued on the home
   node's executor (§2.7, §2.8.1).
3. *Operations* — dispatched by declared :class:`~repro.core.api.Mode`
   through the rules of §2.8.2-§2.8.4 (buffering, log-writes without
   synchronization, early release at suprema, asynchronous release on last
   write).
4. *Commit / abort* — §2.8.5-§2.8.6: join outstanding tasks, wait the
   commit condition per object, apply stray logs, release, validate
   instances, terminate (restoring state and bumping instance epochs on
   abort, which is what drives cascading aborts).

Implementation notes vs. the paper text (also see DESIGN.md):

* §2.8.4 says the post-last-write clone goes to ``st``; that would clobber
  the abort checkpoint, so we clone to the copy buffer ``buf`` (consistent
  with §2.7 and the OptSVA original) — a typo in the paper.
* "Invalid instance" marking is realized as an *instance epoch* on the
  version header: an aborting transaction that restores state bumps the
  epoch; any transaction that observed the prior epoch is doomed at its
  next validity check. Restores (and epoch bumps) only happen for objects
  the aborting transaction actually modified — restoring an unmodified
  object would spuriously doom successors.
"""
from __future__ import annotations

import itertools
import threading
from typing import Any, Callable, Dict, List, Optional, Union

from .api import (
    INF, AbortError, IllegalState, Mode, OpStats, RetrySignal, Suprema,
    SupremumViolation, TransactionError,
)
from .buffers import CopyBuffer, LogBuffer
from .executor import Task
from .registry import Node, Registry, SharedObject

_txn_ids = itertools.count(1)


class ObjectAccess:
    """Transaction-local bookkeeping for one shared object."""

    __slots__ = (
        "shared", "sup", "pv", "rc", "wc", "uc", "st", "buf", "log",
        "seen_instance", "holds_access", "released", "release_task",
        "modified", "lock",
    )

    def __init__(self, shared: SharedObject, sup: Suprema):
        self.shared = shared
        self.sup = sup
        self.pv: int = 0
        self.rc = self.wc = self.uc = 0
        self.st: Optional[CopyBuffer] = None      # abort-restore checkpoint
        self.buf: Optional[CopyBuffer] = None     # post-release local-read buffer
        self.log = LogBuffer(home_node=shared.node)
        self.seen_instance: Optional[int] = None  # epoch observed at checkpoint
        self.holds_access = False                 # passed access condition
        self.released = False                     # lv handed over (or task will)
        self.release_task: Optional[Task] = None  # async buffer/apply task
        self.modified = False                     # we touched live state
        self.lock = threading.Lock()              # task <-> main thread

    @property
    def accessed_directly(self) -> bool:
        return self.holds_access

    def count_for(self, mode: Mode) -> int:
        return {Mode.READ: self.rc, Mode.WRITE: self.wc, Mode.UPDATE: self.uc}[mode]

    def sup_for(self, mode: Mode) -> float:
        return {Mode.READ: self.sup.reads, Mode.WRITE: self.sup.writes,
                Mode.UPDATE: self.sup.updates}[mode]

    def all_suprema_met(self) -> bool:
        return (self.rc == self.sup.reads and self.wc == self.sup.writes
                and self.uc == self.sup.updates)

    def writes_updates_done(self) -> bool:
        return self.wc == self.sup.writes and self.uc == self.sup.updates


class TxProxy:
    """Client-side stub: forwards method calls through the transaction.

    The Atomic RMI 2 proxy object injects OptSVA-CF concurrency control
    around each method invocation (paper §3.1); here the injection point is
    ``Transaction._invoke``.
    """

    __slots__ = ("_txn", "_shared")

    def __init__(self, txn: "Transaction", shared: SharedObject):
        object.__setattr__(self, "_txn", txn)
        object.__setattr__(self, "_shared", shared)

    def __getattr__(self, method: str) -> Callable[..., Any]:
        txn: Transaction = object.__getattribute__(self, "_txn")
        shared: SharedObject = object.__getattribute__(self, "_shared")

        def call(*args: Any, **kwargs: Any) -> Any:
            return txn._invoke(shared, method, args, kwargs)

        call.__name__ = method
        return call

    def __repr__(self) -> str:  # pragma: no cover
        shared = object.__getattribute__(self, "_shared")
        return f"TxProxy({shared.name})"


class Transaction:
    """An OptSVA-CF transaction (Fig. 8 API)."""

    def __init__(self, registry: Optional[Registry] = None, *,
                 irrevocable: bool = False,
                 client_node: Optional[Node] = None,
                 wait_timeout: Optional[float] = None):
        self.id = next(_txn_ids)
        self.registry = registry
        self.irrevocable = irrevocable
        self.client_node = client_node
        self.wait_timeout = wait_timeout
        self.stats = OpStats()
        self._accesses: Dict[SharedObject, ObjectAccess] = {}
        self._order: List[ObjectAccess] = []
        self._started = False
        self._terminated = False
        self._doomed = False

    # ------------------------------------------------------------------ #
    # Preamble (Fig. 8): declaring the access set with suprema.          #
    # ------------------------------------------------------------------ #
    def _declare(self, obj: Union[SharedObject, str], sup: Suprema) -> TxProxy:
        if self._started:
            raise IllegalState("access set must be declared before start()")
        shared = self._resolve(obj)
        sup.validate()
        if shared in self._accesses:
            raise IllegalState(f"object {shared.name!r} already declared")
        acc = ObjectAccess(shared, sup)
        self._accesses[shared] = acc
        self._order.append(acc)
        return TxProxy(self, shared)

    def _resolve(self, obj: Union[SharedObject, str]) -> SharedObject:
        if isinstance(obj, SharedObject):
            return obj
        if self.registry is None:
            raise IllegalState("string lookup requires a registry")
        return self.registry.locate(obj)

    def reads(self, obj: Union[SharedObject, str], max_reads: float = INF) -> TxProxy:
        return self._declare(obj, Suprema(reads=max_reads, writes=0, updates=0))

    def writes(self, obj: Union[SharedObject, str], max_writes: float = INF) -> TxProxy:
        return self._declare(obj, Suprema(reads=0, writes=max_writes, updates=0))

    def updates(self, obj: Union[SharedObject, str], max_updates: float = INF) -> TxProxy:
        return self._declare(obj, Suprema(reads=0, writes=0, updates=max_updates))

    def accesses(self, obj: Union[SharedObject, str], max_reads: float = INF,
                 max_writes: float = INF, max_updates: float = INF) -> TxProxy:
        return self._declare(obj, Suprema(max_reads, max_writes, max_updates))

    # ------------------------------------------------------------------ #
    # Start (§2.8.1)                                                     #
    # ------------------------------------------------------------------ #
    def begin(self) -> None:
        """Acquire private versions atomically; kick off read-only buffering."""
        if self._started:
            raise IllegalState("transaction already started")
        self._started = True
        self._terminated = False
        from .versioning import dispense_versions
        headers = [a.shared.header for a in self._order]
        pvs = dispense_versions(headers)
        for a, pv in zip(self._order, pvs):
            a.pv = pv
        # §2.7/§2.8.1: asynchronously snapshot-and-release read-only objects.
        for a in self._order:
            if a.sup.read_only and a.sup.reads > 0:
                self._spawn_readonly_buffering(a)

    @property
    def _gate_kind(self) -> str:
        """Access gate — or termination gate for irrevocable txns (§2.4)."""
        return "termination" if self.irrevocable else "access"

    def _spawn_readonly_buffering(self, a: ObjectAccess) -> None:
        shared = a.shared

        def code() -> None:
            with shared.header.lock:
                inst = shared.header.instance
            with a.lock:
                a.seen_instance = inst
                a.buf = CopyBuffer(shared.holder.obj, inst, home_node=shared.node)
            # Snapshot taken: the object is immediately released (§2.7).
            shared.header.release_to(a.pv)
            with a.lock:
                a.released = True

        a.release_task = shared.node.executor.submit(
            shared.header, self._gate_kind, a.pv, code,
            name=f"ro-buffer:{shared.name}:T{self.id}")

    # ------------------------------------------------------------------ #
    # Operation dispatch                                                  #
    # ------------------------------------------------------------------ #
    def _invoke(self, shared: SharedObject, method: str, args: tuple,
                kwargs: dict) -> Any:
        if self._terminated:
            raise IllegalState("transaction already terminated")
        if not self._started:
            raise IllegalState("transaction not started; call begin()/start()")
        shared.check_reachable()
        a = self._accesses[shared]
        mode = shared.mode_of(method)
        self._check_supremum(a, mode)
        if mode is Mode.READ:
            v = self._read(a, method, args, kwargs)
            self.stats.reads += 1
        elif mode is Mode.WRITE:
            v = self._write(a, method, args, kwargs)
            self.stats.writes += 1
        else:
            v = self._update(a, method, args, kwargs)
            self.stats.updates += 1
        # heartbeat: only an actual holder (past the access condition and
        # not yet released) counts for the §3.4 failure detector
        if a.holds_access and not a.released:
            shared.touch(self)
        elif a.released:
            shared.clear_holder(self)
        return v

    def _check_supremum(self, a: ObjectAccess, mode: Mode) -> None:
        if a.count_for(mode) + 1 > a.sup_for(mode):
            self._force_abort(
                f"supremum violation: {mode.value} #{a.count_for(mode) + 1} on "
                f"{a.shared.name!r} exceeds bound {a.sup_for(mode)}",
                exc=SupremumViolation)

    # -- read (§2.8.2) -------------------------------------------------------
    def _read(self, a: ObjectAccess, method: str, args: tuple, kwargs: dict) -> Any:
        shared = a.shared
        if a.sup.read_only:
            # Wait for the asynchronous buffering task, read from the buffer.
            assert a.release_task is not None
            a.release_task.join()
            self._validity_check()
            a.rc += 1
            return a.buf.call(method, args, kwargs)
        if a.release_task is not None:
            # Released asynchronously after last write: reads go to the buffer.
            a.release_task.join()
            self._validity_check()
            a.rc += 1
            return a.buf.call(method, args, kwargs)
        if a.released and a.buf is not None:
            # Released synchronously after last write/update.
            self._validity_check()
            a.rc += 1
            return a.buf.call(method, args, kwargs)
        if not a.holds_access:
            self._wait_access_and_checkpoint(a)
            self._apply_log_if_pending(a)
        self._validity_check()
        v = shared.raw_call(method, args, kwargs, from_node=self.client_node)
        a.rc += 1
        if a.all_suprema_met():   # last operation of any kind: release (§2.8.2)
            self._release(a)
        return v

    # -- update (§2.8.3) -----------------------------------------------------
    def _update(self, a: ObjectAccess, method: str, args: tuple, kwargs: dict) -> Any:
        shared = a.shared
        if not a.holds_access:
            self._wait_access_and_checkpoint(a)
            self._apply_log_if_pending(a)
        self._validity_check()
        v = shared.raw_call(method, args, kwargs, from_node=self.client_node)
        a.uc += 1
        a.modified = True
        if a.writes_updates_done():
            # No further writes/updates: buffer for trailing local reads, release.
            with shared.header.lock:
                inst = shared.header.instance
            a.buf = CopyBuffer(shared.holder.obj, inst, home_node=shared.node)
            self._release(a)
        return v

    # -- write (§2.8.4) ------------------------------------------------------
    def _write(self, a: ObjectAccess, method: str, args: tuple, kwargs: dict) -> Any:
        shared = a.shared
        if a.holds_access:
            # Preceding reads/updates hold the object: operate directly.
            self._validity_check()
            v = shared.raw_call(method, args, kwargs, from_node=self.client_node)
            a.wc += 1
            a.modified = True
            if a.writes_updates_done():
                with shared.header.lock:
                    inst = shared.header.instance
                # Paper §2.8.4 says "cloned to st"; that must be buf (see module doc).
                a.buf = CopyBuffer(shared.holder.obj, inst, home_node=shared.node)
                self._release(a)
            return v
        # No preceding reads/updates: log-buffer the write, no synchronization.
        a.log.record(method, args, kwargs)
        a.wc += 1
        if a.wc == a.sup.writes and a.sup.updates == 0:
            # Final write (and no updates will follow): asynchronous apply+release.
            self._spawn_lastwrite_apply(a)
        return None

    def _spawn_lastwrite_apply(self, a: ObjectAccess) -> None:
        shared = a.shared

        def code() -> None:
            with shared.header.lock:
                inst = shared.header.instance
            st = CopyBuffer(shared.holder.obj, inst, home_node=shared.node)
            a.log.apply_to(shared.holder.obj)
            buf = CopyBuffer(shared.holder.obj, inst, home_node=shared.node)
            with a.lock:
                a.seen_instance = inst
                a.st = st
                a.buf = buf
                a.modified = True
                a.holds_access = True
            shared.header.release_to(a.pv)
            with a.lock:
                a.released = True

        a.release_task = shared.node.executor.submit(
            shared.header, self._gate_kind, a.pv, code,
            name=f"lw-apply:{shared.name}:T{self.id}")

    # -- shared helpers --------------------------------------------------------
    def _wait_access_and_checkpoint(self, a: ObjectAccess) -> None:
        shared = a.shared
        h = shared.header
        if self.irrevocable:
            blocked = h.wait_termination(a.pv, timeout=self.wait_timeout)
        else:
            blocked = h.wait_access(a.pv, timeout=self.wait_timeout)
        if blocked:
            self.stats.waits += 1
        shared.check_reachable()
        with h.lock:
            inst = h.instance
        a.seen_instance = inst
        a.st = CopyBuffer(shared.holder.obj, inst, home_node=shared.node)
        a.holds_access = True
        shared.touch(self)

    def _apply_log_if_pending(self, a: ObjectAccess) -> None:
        if len(a.log):
            a.log.apply_to(a.shared.holder.obj)
            a.modified = True

    def _release(self, a: ObjectAccess) -> None:
        if not a.released:
            a.shared.header.release_to(a.pv)
            a.released = True

    def _validity_check(self) -> None:
        """Force an abort as soon as any observed instance was invalidated (§2.3)."""
        for a in self._order:
            with a.lock:
                seen = a.seen_instance
            if seen is not None and a.shared.header.instance != seen:
                self._force_abort(
                    f"object {a.shared.name!r} was invalidated by a cascading abort")

    def _force_abort(self, msg: str, exc: type = AbortError) -> None:
        self._do_abort()
        self.stats.aborts += 1
        err = exc(msg) if exc is SupremumViolation else exc(msg, forced=True)
        raise err

    # ------------------------------------------------------------------ #
    # Commit (§2.8.5)                                                    #
    # ------------------------------------------------------------------ #
    def commit(self) -> None:
        if self._terminated:
            raise IllegalState("transaction already terminated")
        if not self._started:
            raise IllegalState("transaction not started")
        # 1. Wait for extant asynchronous tasks.
        task_error: Optional[BaseException] = None
        for a in self._order:
            if a.release_task is not None:
                try:
                    a.release_task.join()
                except TransactionError as e:
                    task_error = e
        if task_error is not None:
            self._do_abort()
            self.stats.aborts += 1
            raise AbortError(f"asynchronous task failed: {task_error}", forced=True)
        # 2. Wait until the commit condition holds for every object.
        for a in self._order:
            if a.shared.header.wait_termination(a.pv, timeout=self.wait_timeout):
                self.stats.waits += 1
        # 3. Checkpoint untouched objects; apply left-over logs; release.
        for a in self._order:
            h = a.shared.header
            if a.seen_instance is None:
                with h.lock:
                    a.seen_instance = h.instance
                a.st = CopyBuffer(a.shared.holder.obj, a.seen_instance,
                                  home_node=a.shared.node)
            if len(a.log):
                a.log.apply_to(a.shared.holder.obj)
                a.modified = True
            self._release(a)
        # 4. Validity check: abort if anything we observed was invalidated.
        doomed = any(
            a.seen_instance is not None and a.shared.header.instance != a.seen_instance
            for a in self._order)
        if doomed:
            self._do_abort()
            self.stats.aborts += 1
            raise AbortError("commit-time validation failed (cascading abort)",
                             forced=True)
        # 5. Terminate: advance ltv on every object.
        for a in self._order:
            a.shared.header.terminate_to(a.pv)
            a.shared.clear_holder(self)
        self._terminated = True

    # ------------------------------------------------------------------ #
    # Abort (§2.8.6) and retry                                            #
    # ------------------------------------------------------------------ #
    def abort(self) -> None:
        """Manual abort (Fig. 9). Raises AbortError to unwind the atomic block."""
        self._do_abort()
        self.stats.aborts += 1
        raise AbortError("transaction aborted manually", forced=False)

    def retry(self) -> None:
        """Manual retry: abort, then signal ``start`` to re-run the block."""
        self._do_abort()
        self.stats.retries += 1
        raise RetrySignal("transaction retry requested")

    def _do_abort(self) -> None:
        if self._terminated:
            return
        # 1. Wait for extant tasks (they may still be mutating state).
        for a in self._order:
            if a.release_task is not None:
                try:
                    a.release_task.join()
                except TransactionError:
                    pass
        # 2. Wait for the commit condition per object.
        for a in self._order:
            try:
                a.shared.header.wait_termination(a.pv, timeout=self.wait_timeout)
            except TimeoutError:
                pass  # fault-tolerance path: predecessor crashed; monitor cleans up
        # 3. Restore modified objects from their checkpoints, oldest-restore-wins.
        for a in self._order:
            h = a.shared.header
            with a.lock:
                seen, st, modified = a.seen_instance, a.st, a.modified
            if st is not None and modified:
                with h.lock:
                    if h.instance == seen:
                        # Not already restored to an older version: restore + invalidate.
                        st.restore_into(a.shared.holder)
                        h.instance += 1
        # 4. Release and terminate every object.
        for a in self._order:
            self._release(a)
            a.shared.header.terminate_to(a.pv)
            a.shared.clear_holder(self)
        self._terminated = True

    # ------------------------------------------------------------------ #
    # start(): run an atomic block with commit/abort/retry handling       #
    # ------------------------------------------------------------------ #
    def start(self, body: Callable[["Transaction"], Any], *,
              max_retries: int = 64) -> Any:
        """Run ``body(self)``; commit on fall-through (Fig. 9 semantics).

        ``retry()`` re-runs the block under a fresh transaction incarnation
        (new private versions, same declared access set). Manual and forced
        aborts propagate as :class:`AbortError` after rollback completes.
        """
        attempts = 0
        while True:
            attempts += 1
            if not self._started:
                self.begin()
            try:
                result = body(self)
            except RetrySignal:
                if attempts > max_retries:
                    raise AbortError("retry limit exceeded", forced=True) from None
                self._reincarnate()
                continue
            except AbortError:
                raise  # rollback already performed by abort()/_force_abort
            except BaseException:
                # Any exception escaping the block — including remote-object
                # failures (§3.4) — aborts the transaction (§3.2).
                if not self._terminated:
                    self._do_abort()
                    self.stats.aborts += 1
                raise
            if not self._terminated:
                self.commit()
            return result

    def _reincarnate(self) -> None:
        """Rebuild per-object records for a retry: fresh versions, same set."""
        fresh: List[ObjectAccess] = []
        mapping: Dict[SharedObject, ObjectAccess] = {}
        for a in self._order:
            na = ObjectAccess(a.shared, a.sup)
            fresh.append(na)
            mapping[a.shared] = na
        self._order = fresh
        self._accesses = mapping
        self._started = False
        self._terminated = False
        self.begin()

    # -- context-manager sugar -------------------------------------------------
    def __enter__(self) -> "Transaction":
        if not self._started:
            self.begin()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            if not self._terminated:
                self.commit()
            return False
        if not self._terminated and not isinstance(exc, TransactionError):
            self._do_abort()
            self.stats.aborts += 1
        return False
