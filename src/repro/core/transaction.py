"""OptSVA-CF transactions (paper §2.8, API per Figs. 8-9).

The transaction life cycle:

1. *Preamble* — the client declares its access set with ``reads`` /
   ``writes`` / ``updates`` / ``accesses``, optionally with suprema.
2. *Start* — private versions are dispensed atomically for the whole access
   set (global-order version-lock acquisition, §2.10.2); for every
   *read-only* object an asynchronous buffering task is enqueued on the home
   node's executor (§2.7, §2.8.1).
3. *Operations* — dispatched by declared :class:`~repro.core.api.Mode`
   through the rules of §2.8.2-§2.8.4 (buffering, log-writes without
   synchronization, early release at suprema, asynchronous release on last
   write).
4. *Commit / abort* — §2.8.5-§2.8.6: join outstanding tasks, wait the
   commit condition per object, apply stray logs, release, validate
   instances, terminate (restoring state and bumping instance epochs on
   abort, which is what drives cascading aborts).

Implementation notes vs. the paper text (also see DESIGN.md):

* §2.8.4 says the post-last-write clone goes to ``st``; that would clobber
  the abort checkpoint, so we clone to the copy buffer ``buf`` (consistent
  with §2.7 and the OptSVA original) — a typo in the paper.
* "Invalid instance" marking is realized as an *instance epoch* on the
  version header: an aborting transaction that restores state bumps the
  epoch; any transaction that observed the prior epoch is doomed at its
  next validity check. Restores (and epoch bumps) only happen for objects
  the aborting transaction actually modified — restoring an unmodified
  object would spuriously doom successors.

Transport boundary (DESIGN.md §3.1): every operation that touches object
*state* — waiting a gate and checkpointing, snapshotting a buffer, applying
a log, reading through a buffer, restoring on abort — is a method of
:class:`ObjectAccess`, executed where the object lives. This in-process
implementation runs them directly against ``shared.holder``; the TCP
transport (``repro.net``) subclasses :class:`ObjectAccess` so the same
operations become single RPCs executed *on the home node* and only control
information (versions, instance epochs, return values) crosses the wire —
the CF model's delegation of computation to data. :class:`Transaction`
itself is transport-agnostic protocol sequencing.
"""
from __future__ import annotations

import itertools
import threading
from typing import Any, Callable, Dict, List, Optional, Union

from .api import (
    INF, AbortError, IllegalState, InstanceInvalidated, Mode, OpStats,
    RetrySignal, Suprema, SupremumViolation, TransactionError,
)
from .buffers import CopyBuffer, LogBuffer
from .executor import Task
from .registry import Node, Registry, SharedObject
from .versioning import skip_version

from repro.obs import txtrace as _txtrace

_txn_ids = itertools.count(1)


class Completed:
    """Already-resolved completion handle (the in-process "future").

    The commit/abort hot paths issue their per-node batched operations
    first and await results second (scatter-gather); the in-process
    transport executes at issue time and hands back one of these, so
    :class:`Transaction` sequencing stays transport-agnostic.
    """

    __slots__ = ("_value",)

    def __init__(self, value: Any = None):
        self._value = value

    def result(self, timeout: Optional[float] = None) -> Any:
        return self._value


class ObjectAccess:
    """Transaction-local bookkeeping for one shared object, plus the
    home-node state operations of §2.7-§2.8 (the transport boundary).

    The base class is the in-process transport: state operations execute
    directly against ``shared.holder`` / ``shared.header``. Remote
    transports override the *delegation boundary* methods below so the same
    operations run on the object's home node.
    """

    __slots__ = (
        "txn", "shared", "sup", "pv", "rc", "wc", "uc", "st", "buf", "log",
        "seen_instance", "holds_access", "released", "release_task",
        "modified", "terminated", "lock",
    )

    #: version-lock domain for start-time dispensing (§2.10.2): ``None``
    #: means the in-process domain (per-header locks in uid order); remote
    #: accesses return a sortable per-node key so every client acquires
    #: node-level locks in the same global order.
    dispense_domain: Optional[tuple] = None

    def __init__(self, txn: "Transaction", shared: SharedObject, sup: Suprema):
        self.txn = txn
        self.shared = shared
        self.sup = sup
        self.pv: int = 0
        self.rc = self.wc = self.uc = 0
        self.st: Optional[CopyBuffer] = None      # abort-restore checkpoint
        self.buf: Optional[CopyBuffer] = None     # post-release local-read buffer
        self.log = LogBuffer(home_node=shared.node)
        self.seen_instance: Optional[int] = None  # epoch observed at checkpoint
        self.holds_access = False                 # passed access condition
        self.released = False                     # lv handed over (or task will)
        self.release_task: Optional[Task] = None  # async buffer/apply task
        self.modified = False                     # we touched live state
        self.terminated = False                   # ltv advanced past us
        self.lock = threading.Lock()              # task <-> main thread

    @property
    def accessed_directly(self) -> bool:
        return self.holds_access

    def count_for(self, mode: Mode) -> int:
        return {Mode.READ: self.rc, Mode.WRITE: self.wc, Mode.UPDATE: self.uc}[mode]

    def sup_for(self, mode: Mode) -> float:
        return {Mode.READ: self.sup.reads, Mode.WRITE: self.sup.writes,
                Mode.UPDATE: self.sup.updates}[mode]

    def all_suprema_met(self) -> bool:
        return (self.rc == self.sup.reads and self.wc == self.sup.writes
                and self.uc == self.sup.updates)

    def writes_updates_done(self) -> bool:
        return self.wc == self.sup.writes and self.uc == self.sup.updates

    # ------------------------------------------------------------------ #
    # Delegation boundary: state operations, executed at the home node.  #
    # ------------------------------------------------------------------ #
    def _ro_buffer_code(self) -> None:
        """§2.7 task body: snapshot to ``buf``, then release immediately.
        Shared with the node server's session records, which subclass this
        access and wrap the body with §3.4 expiry checks."""
        if _txtrace.enabled:
            t0 = self._obs_tracer().now()
            self._ro_buffer_body()
            self._obs_span("ro_buffer", t0, detail=self.shared.name)
        else:
            self._ro_buffer_body()

    def _ro_buffer_body(self) -> None:
        shared = self.shared
        with shared.header.lock:
            inst = shared.header.instance
        with self.lock:
            self.seen_instance = inst
            self.buf = CopyBuffer(shared.holder.obj, inst,
                                  home_node=shared.node)
        # Snapshot taken: the object is immediately released (§2.7).
        shared.header.release_to(self.pv)
        with self.lock:
            self.released = True

    def _lw_apply_code(self) -> None:
        """§2.8.4 task body: checkpoint, apply the write log, release."""
        if _txtrace.enabled:
            t0 = self._obs_tracer().now()
            self._lw_apply_body()
            self._obs_span("lw_apply", t0, detail=self.shared.name)
        else:
            self._lw_apply_body()

    def _lw_apply_body(self) -> None:
        shared = self.shared
        with shared.header.lock:
            inst = shared.header.instance
        st = CopyBuffer(shared.holder.obj, inst, home_node=shared.node)
        self.log.apply_to(shared.holder.obj)
        buf = CopyBuffer(shared.holder.obj, inst, home_node=shared.node)
        with self.lock:
            self.seen_instance = inst
            self.st = st
            self.buf = buf
            self.modified = True
            self.holds_access = True
        shared.header.release_to(self.pv)
        with self.lock:
            self.released = True

    def _owner_label(self) -> str:
        return f"T{self.txn.id}"

    # -- observability (repro.obs; call only under ``txtrace.enabled``) -----
    def _obs_uid(self) -> str:
        """Correlation uid matching the wire form's ``#<id>[r<inc>]``
        tail (remote._txn_uid) so client and server spans of one
        transaction merge into one flow. The node server's session
        access overrides this with the full wire uid."""
        inc = getattr(self.txn, "incarnation", 0)
        tid = self.txn.id
        return f"#{tid}r{inc}" if inc else f"#{tid}"

    def _obs_tracer(self):
        """The owning site's tracer: where the state lives (stamped on
        the header by the node server), else this thread's client site."""
        return self.shared.header.obs_tracer or _txtrace.current()

    def _obs_span(self, kind: str, t0: float, **kw: Any) -> None:
        tr = self._obs_tracer()
        tr.emit(kind, t0, tr.now() - t0, txn=self._obs_uid(),
                inc=getattr(self.txn, "incarnation", 0), pv=self.pv, **kw)

    def _obs_instant(self, kind: str, **kw: Any) -> None:
        tr = self._obs_tracer()
        tr.emit(kind, tr.now(), 0.0, txn=self._obs_uid(),
                inc=getattr(self.txn, "incarnation", 0), pv=self.pv, **kw)

    def _submit_task(self, label: str, kind: str,
                     code: Callable[[], None]) -> "Task":
        """Hand a gated task to the home node's executor. The node server
        overrides this to defer ready tasks off its reader thread and to
        push a completion note to the client when the task finishes."""
        return self.shared.node.executor.submit(
            self.shared.header, kind, self.pv, code,
            name=f"{label}:{self.shared.name}:{self._owner_label()}")

    def spawn_ro_buffer(self, kind: str) -> None:
        """§2.7: asynchronously snapshot-and-release a read-only object."""
        self.release_task = self._submit_task("ro-buffer", kind,
                                              self._ro_buffer_code)

    def spawn_lastwrite_apply(self, kind: str) -> None:
        """§2.8.4: asynchronously checkpoint, apply the write log, release."""
        self.release_task = self._submit_task("lw-apply", kind,
                                              self._lw_apply_code)

    def join_release_task(self) -> None:
        """Wait for the outstanding asynchronous buffer/apply task."""
        if self.release_task is not None:
            self.release_task.join()

    def open_access(self, kind: str, timeout: Optional[float]) -> bool:
        """Wait the access (or termination) gate, then checkpoint (§2.8.2).

        Returns True iff the caller actually blocked."""
        shared = self.shared
        h = shared.header
        if kind == "termination":
            blocked = h.wait_termination(self.pv, timeout=timeout)
        else:
            blocked = h.wait_access(self.pv, timeout=timeout)
        shared.check_reachable()
        with h.lock:
            inst = h.instance
        self.seen_instance = inst
        self.st = CopyBuffer(shared.holder.obj, inst, home_node=shared.node)
        self.holds_access = True
        shared.touch(self.txn)
        return blocked

    def open_and_call(self, kind: str, timeout: Optional[float], method: str,
                      args: tuple, kwargs: dict, *, modifies: bool,
                      validity: Optional[Callable[[], None]] = None):
        """First direct access of §2.8.2-3 fused: wait the gate, checkpoint,
        apply any buffered writes, execute the method. One operation at the
        home node — remote transports collapse it into a single RPC.
        ``validity`` (the transaction's cross-object §2.3 check) runs after
        the gate wait and before the call, preserving the in-process
        check-before-execute order; remote transports ignore it — their
        per-object check is enforced by the home node inside the RPC,
        exactly as on every other remote operation. Returns
        ``(blocked, value)``."""
        blocked = self.open_access(kind, timeout)
        self.apply_log()
        if validity is not None:
            validity()
        v = self.raw_call(method, args, kwargs, modifies=modifies)
        return blocked, v

    def raw_call(self, method: str, args: tuple, kwargs: dict, *,
                 modifies: bool) -> Any:
        """Execute a method against the live state at the home node."""
        if _txtrace.enabled:
            t0 = self._obs_tracer().now()
            v = self.shared.raw_call(method, args, kwargs,
                                     from_node=self.txn.client_node)
            self._obs_span("service", t0,
                           detail=f"{self.shared.name}.{method}")
        else:
            v = self.shared.raw_call(method, args, kwargs,
                                     from_node=self.txn.client_node)
        if modifies:
            self.modified = True
        return v

    def write_held(self, method: str, args: tuple, kwargs: dict) -> None:
        """§2.8.4 write while the object is held (preceding reads/updates
        passed the access condition). Pure writes are value-less in the
        paper's model, so transports may pipeline the call: the remote
        transport turns it into a one-way message (deferred errors) once
        the transaction has no reads left on the object."""
        self.raw_call(method, args, kwargs, modifies=True)

    # Operation fusion (``raw_call_batch``/``open_and_call_batch``) is a
    # remote-transport surface only: the fusion guard in Transaction.
    # _fusable_run never fuses accesses whose dispense_domain is None (a
    # per-op in-process call is already as cheap as a batched one), so no
    # base implementation exists — see RemoteObjectAccess.

    def buf_call(self, method: str, args: tuple, kwargs: dict) -> Any:
        """Execute a read against the post-release copy buffer (§2.7)."""
        return self.buf.call(method, args, kwargs)

    def record_write(self, method: str, args: tuple, kwargs: dict) -> None:
        """§2.8.4: log a pure write with no synchronization."""
        self.log.record(method, args, kwargs)

    def apply_log(self) -> None:
        """Replay the pending write log against the live state."""
        if len(self.log):
            self.log.apply_to(self.shared.holder.obj)
            self.modified = True

    def snapshot_buf(self) -> None:
        """Clone live state to ``buf`` for trailing local reads (§2.8.3-4)."""
        shared = self.shared
        with shared.header.lock:
            inst = shared.header.instance
        self.buf = CopyBuffer(shared.holder.obj, inst, home_node=shared.node)

    def snapshot_and_release(self) -> None:
        """§2.8.3-4 release point, fused: buffer for trailing local reads,
        then release. Remote transports turn this into one pipelined
        one-way message — the writer's hot path never waits for it."""
        self.snapshot_buf()
        self.release()

    def ensure_checkpoint(self) -> None:
        """Commit step 3: checkpoint an object never accessed directly."""
        if self.seen_instance is None:
            h = self.shared.header
            with h.lock:
                self.seen_instance = h.instance
            self.st = CopyBuffer(self.shared.holder.obj, self.seen_instance,
                                 home_node=self.shared.node)

    def release(self) -> None:
        if not self.released:
            self.shared.header.release_to(self.pv)
            self.released = True
            if _txtrace.enabled:
                self._obs_instant("release", detail=self.shared.name)

    def wait_termination(self, timeout: Optional[float]) -> bool:
        """Wait the commit condition (§2.8.5). True iff actually blocked."""
        return self.shared.header.wait_termination(self.pv, timeout=timeout)

    def valid(self) -> bool:
        """False iff the observed instance was invalidated (§2.3)."""
        with self.lock:
            seen = self.seen_instance
        return seen is None or self.shared.header.instance == seen

    def valid_commit(self) -> bool:
        """Commit-time validation (step 4 of §2.8.5). In-process this is
        the same check as :meth:`valid`; remote transports override it with
        an authoritative home-node query (per-op checks there are enforced
        server-side instead of client-side)."""
        return self.valid()

    def rollback(self) -> None:
        """Abort step 3: restore from the checkpoint, oldest-restore-wins
        (version-aware: see :meth:`VersionHeader.restore_allowed` — a
        younger transaction's restore must never suppress ours)."""
        h = self.shared.header
        with self.lock:
            seen, st, modified = self.seen_instance, self.st, self.modified
        if st is not None and modified:
            with h.lock:
                if h.restore_allowed(seen, self.pv):
                    st.restore_into(self.shared.holder)
                    h.note_restore(self.pv)
                    h.instance += 1

    def terminate(self) -> None:
        """Advance ltv past us and drop the failure-detector hold (§2.8.5-6)."""
        self.shared.header.terminate_to(self.pv)
        self.shared.clear_holder(self.txn)
        self.terminated = True
        if _txtrace.enabled:
            self._obs_instant("terminate", detail=self.shared.name)

    def prepare_start(self) -> None:
        """Transport hook, called before any version lock is acquired
        (remote transports register liveness here)."""

    def dispense_many(self, domains: List[List["ObjectAccess"]]) -> None:
        """Transport hook: lock-and-dispense for several remote dispense
        domains, already sorted in the global 2PL order. Every access
        class with a non-``None`` ``dispense_domain`` must override this;
        the TCP transport *chains* the request server-to-server (node k
        forwards to node k+1), so a multi-node start costs the client one
        round trip while gates are still acquired in global order and held
        until :meth:`release_version_locks` (2PL preserved)."""
        raise NotImplementedError(
            "remote dispense domains must implement dispense_many "
            "(§2.10.2 global-order lock-and-dispense)")

    def abandon(self) -> None:
        """Failed-start cleanup: skip this access's dispensed version *in
        chain order* (never bypassing a live predecessor's unreleased
        state) without touching object state — nothing was accessed yet."""
        skip_version(self.shared.header, self.pv)

    def valid_commit_batch(self, accs: List["ObjectAccess"]) -> bool:
        """Commit-time validation for all accesses of one dispense domain
        in one step (remote transports batch this into a single RPC)."""
        return all(a.valid_commit() for a in accs)

    # ------------------------------------------------------------------ #
    # Issue/await split: per-domain batched steps of commit and abort.   #
    # The in-process transport executes at issue time and returns        #
    # Completed; remote transports issue one pipelined RPC per node and  #
    # return a wire future, so the per-node round trips of one commit    #
    # step overlap (scatter-gather) instead of accumulating serially.    #
    # ------------------------------------------------------------------ #
    def commit_prep(self) -> None:
        """Commit step 3 for one access: checkpoint if never accessed,
        apply any left-over write log, release."""
        self.ensure_checkpoint()
        self.apply_log()
        self.release()

    def wait_termination_async(self, timeout: Optional[float]) -> Completed:
        """Issue the commit-condition wait (§2.8.5). ``result()`` returns
        True iff the waiter actually blocked."""
        return Completed(self.wait_termination(timeout))

    def wait_termination_batch_async(self, accs: List["ObjectAccess"],
                                     timeout: Optional[float],
                                     best_effort: bool = False) -> Completed:
        """Issue commit step 2 for all accesses of this dispense domain;
        ``result()`` is the number of waits that actually blocked. Remote
        transports run the whole batch in one RPC, and the batches of
        different home nodes wait concurrently. ``best_effort`` (the abort
        path) keeps waiting the remaining accesses when one times out."""
        blocked = 0
        for a in accs:
            try:
                if a.wait_termination(timeout):
                    blocked += 1
            except (TimeoutError, TransactionError):
                if not best_effort:
                    raise
        return Completed(blocked)

    def commit_wave1_async(self, accs: List["ObjectAccess"],
                           timeout: Optional[float]) -> Completed:
        """Commit steps 2-4 for this dispense domain, issued as one unit:
        wait the commit condition per object, then checkpoint/apply/release
        per object, then validate the batch. ``result()`` is ``(blocked,
        ok)`` — how many waits blocked, and the validation verdict. Remote
        transports run the whole wave in a single RPC per node, and the
        waves of different home nodes overlap; termination (step 5) stays a
        separate wave because no object may terminate-as-committed until
        *every* domain's validation verdict is in."""
        blocked = sum(1 for a in accs if a.wait_termination(timeout))
        for a in accs:
            a.commit_prep()
        return Completed((blocked, self.valid_commit_batch(accs)))

    def valid_commit_batch_async(self, accs: List["ObjectAccess"]) -> Completed:
        """Issue commit step 4 for this domain; ``result()`` is the verdict."""
        return Completed(self.valid_commit_batch(accs))

    def commit_solo_async(self, accs: List["ObjectAccess"],
                          timeout: Optional[float]) -> Completed:
        """Commit steps 2-5 when the whole access set lives in ONE dispense
        domain: the validation verdict is local to it, so termination can
        be decided in the same unit — one RPC for the entire commit on a
        remote transport. ``result()`` is ``(blocked, ok)``; on ``ok`` the
        accesses are already terminated, on failure nothing terminated."""
        blocked, ok = self.commit_wave1_async(accs, timeout).result()
        if ok:
            self.finish_batch_async(accs).result()
        return Completed((blocked, ok))

    def finish_batch_async(self, accs: List["ObjectAccess"],
                           best_effort: bool = False) -> Completed:
        """Issue release+terminate for this domain (commit step 5 / abort
        step 4). ``best_effort`` swallows per-access transactional errors —
        the abort path must keep going past dead home nodes."""
        for a in accs:
            try:
                a.release()
                a.terminate()
            except TransactionError:
                if not best_effort:
                    raise
        return Completed()

    def rollback_batch_async(self, accs: List["ObjectAccess"]) -> Completed:
        """Issue abort step 3 (checkpoint restores) for this domain;
        always best-effort (an unreachable home node restores via §3.4)."""
        for a in accs:
            try:
                a.rollback()
            except TransactionError:
                pass
        return Completed()

    def raise_deferred(self) -> None:
        """Sync point: surface deferred errors of this access's pipelined
        one-way operations (remote transports override; in-process
        operations are synchronous, so there is never anything deferred)."""

    def note_contact(self) -> None:
        """§3.4 heartbeat: an actual holder refreshes the failure detector."""
        if self.holds_access and not self.released:
            self.shared.touch(self.txn)
        elif self.released:
            self.shared.clear_holder(self.txn)

    def check_reachable(self) -> None:
        self.shared.check_reachable()

    def finish_session(self) -> None:
        """Transport hook: the transaction terminated on every object."""


class CommuteAccess(ObjectAccess):
    """In-process access restricted to one commuting method class
    (DESIGN.md §12).

    While the object's commute group is *active* (``cg_active``), this
    access holds the group's shared private version: its deltas live only
    in the log buffer until terminate, where they fold into live state
    under the per-class merge lock — no checkpoint, no early release, no
    version-gate wait. If the group could not be joined (another class
    active, snapped group, chain not quiescent) the access falls back to
    exact dispensing and behaves as a plain §2.8.4 log-write access.
    """

    __slots__ = ("cg_active", "_cg_done", "_cg_aborted")

    def __init__(self, txn: "Transaction", shared: SharedObject,
                 sup: Suprema):
        super().__init__(txn, shared, sup)
        self.cg_active = False
        self._cg_done = False
        self._cg_aborted = False

    @property
    def commute_cls(self) -> str:
        return self.sup.commutes

    def record_commute(self, method: str, args: tuple, kwargs: dict) -> None:
        """Buffer one commuting delta (applied at the fold, never before)."""
        self.log.record(method, args, kwargs)

    def join_group_locked(self) -> None:
        """Join (or form) the object's commute group — called by
        :func:`dispense_for` while the header lock is held, inside the 2PL
        window. Falls back to exact dispensing when joining is refused."""
        pv = self.shared.header.commute_join(self.commute_cls)
        if pv:
            self.pv = pv
            self.cg_active = True
        else:
            self.pv = self.shared.header.dispense()

    # While the group is active the access never touches live state before
    # the fold: no checkpoint to take, nothing to release or validate.
    def ensure_checkpoint(self) -> None:
        if not self.cg_active:
            super().ensure_checkpoint()

    def commit_prep(self) -> None:
        if not self.cg_active:
            super().commit_prep()

    def release(self) -> None:
        # An early lv advance would open exact successors' gates before
        # the group's folds finished — release rides the dissolve instead.
        if not self.cg_active:
            super().release()

    def wait_termination(self, timeout: Optional[float]) -> bool:
        if self.cg_active:
            return False   # ltv == cg_pv - 1 by construction: never blocks
        return super().wait_termination(timeout)

    def valid(self) -> bool:
        return True if self.cg_active else super().valid()

    def valid_commit(self) -> bool:
        return True if self.cg_active else super().valid_commit()

    def rollback(self) -> None:
        if not self.cg_active:
            super().rollback()
            return
        # Undelivered deltas are simply discarded: live state was never
        # touched, so there is no restore and no instance bump (§12 —
        # which is also why aborting a commute member dooms nobody).
        self._cg_aborted = True
        self.log.entries.clear()

    def terminate(self) -> None:
        if not self.cg_active:
            super().terminate()
            return
        if self._cg_done:
            return
        self._cg_done = True
        h = self.shared.header
        if not self._cg_aborted and len(self.log):
            with h.commute_merge_lock(self.commute_cls):
                self.log.apply_to(self.shared.holder.obj)
                self.modified = True
        else:
            self.log.entries.clear()
        self.shared.clear_holder(self.txn)
        self.terminated = True
        h.commute_leave()
        if _txtrace.enabled:
            self._obs_instant("terminate", detail=self.shared.name)

    def abandon(self) -> None:
        if not self.cg_active:
            super().abandon()
            return
        self.rollback()
        self.terminate()


def dispense_for(order: List[ObjectAccess]) -> None:
    """Atomically dispense private versions for a (possibly multi-transport)
    access set (paper §2.10.2).

    Version-lock *domains* are acquired in a globally consistent order: the
    in-process domain first (per-header locks in ``uid`` order), then each
    remote node in ``dispense_domain`` sort order, one batched
    lock-and-dispense RPC per node. All locks are held until every domain
    has dispensed — 2PL on version locks — which keeps private-version
    orders consistent across objects (no circular waits later), then
    released. Cost over the wire: one round-trip per *node* plus one
    release round-trip, not one per object.
    """
    local = [a for a in order if a.dispense_domain is None]
    remote: Dict[tuple, List[ObjectAccess]] = {}
    for a in order:
        if a.dispense_domain is not None:
            remote.setdefault(a.dispense_domain, []).append(a)

    # Commute-only fast path (DESIGN.md §12): a transaction touching ONE
    # object on ONE remote domain through a commute-declared access needs
    # no start-time coordination at all — the dispense RPC is skipped and
    # the home node lazily joins the commute group at the first delta (or
    # at commit). If the server must fall back to exact dispensing there,
    # the late join is equivalent to a late start on a single node. The
    # single-OBJECT bound is load-bearing: a transaction that late-joins
    # two objects acquires their versions non-atomically, so its order
    # against a concurrent start-time-dispensed transaction can invert
    # between the objects — a circular wait 2PL start-time dispensing
    # exists to rule out (found by the commute seed sweep). Multi-object
    # commute transactions dispense at start like everyone else; their
    # group joins happen inside the 2PL window (dispense_batch's commute
    # map / join_group_locked below), which keeps cross-object order
    # consistent while still merging their deltas.
    if not local and len(remote) == 1:
        (accs,) = remote.values()
        if len(accs) == 1 and all(
                getattr(a, "can_defer_start", False) for a in accs):
            accs[0].prepare_start()
            for a in accs:
                a.defer_start()
            return

    # Liveness registration first, before any version lock is held —
    # presence setup may block in a TCP connect.
    for accs in remote.values():
        accs[0].prepare_start()

    locked_local = sorted({a.shared.header for a in local},
                          key=lambda h: h.uid)
    for h in locked_local:
        h.lock.acquire()
    remote_domains = [remote[d] for d in sorted(remote)]
    dispensed_remote = False
    try:
        if remote_domains:
            # One chained lock-and-dispense over all remote domains in
            # global order; every domain's gates stay held (2PL).
            remote_domains[0][0].dispense_many(remote_domains)
            dispensed_remote = True
        for a in local:
            join = getattr(a, "join_group_locked", None)
            if join is not None:
                join()       # commute group join, exact fallback inside
            else:
                a.pv = a.shared.header.dispense()
    finally:
        for h in reversed(locked_local):
            h.lock.release()
        if dispensed_remote:
            for accs in remote_domains:
                try:
                    accs[0].release_version_locks()
                except TransactionError:
                    pass   # that node died; its reaper frees the gates


class TxProxy:
    """Client-side stub: forwards method calls through the transaction.

    The Atomic RMI 2 proxy object injects OptSVA-CF concurrency control
    around each method invocation (paper §3.1); here the injection point is
    ``Transaction._invoke``.
    """

    __slots__ = ("_txn", "_shared")

    def __init__(self, txn: "Transaction", shared: SharedObject):
        object.__setattr__(self, "_txn", txn)
        object.__setattr__(self, "_shared", shared)

    def __getattr__(self, method: str) -> Callable[..., Any]:
        txn: Transaction = object.__getattribute__(self, "_txn")
        shared: SharedObject = object.__getattribute__(self, "_shared")

        def call(*args: Any, **kwargs: Any) -> Any:
            return txn._invoke(shared, method, args, kwargs)

        call.__name__ = method
        return call

    def __repr__(self) -> str:  # pragma: no cover
        shared = object.__getattribute__(self, "_shared")
        return f"TxProxy({shared.name})"


class Transaction:
    """An OptSVA-CF transaction (Fig. 8 API)."""

    def __init__(self, registry: Optional[Registry] = None, *,
                 irrevocable: bool = False,
                 client_node: Optional[Node] = None,
                 wait_timeout: Optional[float] = None):
        self.id = next(_txn_ids)
        #: retry incarnation counter: remote transports key their sessions
        #: and task/deferred-error bookkeeping on (id, incarnation), so a
        #: late pipelined notification from a rolled-back incarnation can
        #: never pollute its successor.
        self.incarnation = 0
        self.registry = registry
        self.irrevocable = irrevocable
        self.client_node = client_node
        self.wait_timeout = wait_timeout
        self.stats = OpStats()
        self._accesses: Dict[SharedObject, ObjectAccess] = {}
        self._order: List[ObjectAccess] = []
        self._started = False
        self._terminated = False
        self._doomed = False
        self._obs_t0 = 0.0   # client-window start (txtrace; set in begin())

    # -- observability (client-side spans; gate on ``txtrace.enabled``) ------
    def _obs_uid(self) -> str:
        return (f"#{self.id}r{self.incarnation}" if self.incarnation
                else f"#{self.id}")

    def _obs_span(self, kind: str, t0: float, **kw: Any) -> None:
        tr = _txtrace.current()
        tr.emit(kind, t0, tr.now() - t0, txn=self._obs_uid(),
                inc=self.incarnation, **kw)

    def _obs_instant(self, kind: str, **kw: Any) -> None:
        tr = _txtrace.current()
        tr.emit(kind, tr.now(), 0.0, txn=self._obs_uid(),
                inc=self.incarnation, **kw)

    # ------------------------------------------------------------------ #
    # Preamble (Fig. 8): declaring the access set with suprema.          #
    # ------------------------------------------------------------------ #
    def _declare(self, obj: Union[SharedObject, str], sup: Suprema) -> TxProxy:
        if self._started:
            raise IllegalState("access set must be declared before start()")
        shared = self._resolve(obj)
        sup.validate()
        if shared in self._accesses:
            raise IllegalState(f"object {shared.name!r} already declared")
        acc = shared.make_access(self, sup)
        self._accesses[shared] = acc
        self._order.append(acc)
        return TxProxy(self, shared)

    def _resolve(self, obj: Union[SharedObject, str]) -> SharedObject:
        if not isinstance(obj, str):
            return obj   # any shared-object duck type (in-proc or remote)
        if self.registry is None:
            raise IllegalState("string lookup requires a registry")
        return self.registry.locate(obj)

    def reads(self, obj: Union[SharedObject, str], max_reads: float = INF) -> TxProxy:
        return self._declare(obj, Suprema(reads=max_reads, writes=0, updates=0))

    def writes(self, obj: Union[SharedObject, str], max_writes: float = INF) -> TxProxy:
        return self._declare(obj, Suprema(reads=0, writes=max_writes, updates=0))

    def updates(self, obj: Union[SharedObject, str], max_updates: float = INF) -> TxProxy:
        return self._declare(obj, Suprema(reads=0, writes=0, updates=max_updates))

    def accesses(self, obj: Union[SharedObject, str], max_reads: float = INF,
                 max_writes: float = INF, max_updates: float = INF) -> TxProxy:
        return self._declare(obj, Suprema(max_reads, max_writes, max_updates))

    def commutes(self, obj: Union[SharedObject, str], max_ops: float = INF,
                 cls: Optional[str] = None) -> TxProxy:
        """Declare a *commute-restricted* access (DESIGN.md §12): the
        transaction promises to touch ``obj`` only through methods of the
        commuting class ``cls`` (inferred when the object declares exactly
        one). Such invocations skip version-gated dispensing and merge as
        deltas at the home node."""
        shared = self._resolve(obj)
        if cls is None:
            classes = sorted(set(self._commute_classes(shared).values()))
            if len(classes) != 1:
                raise IllegalState(
                    f"object {shared.name!r} declares {len(classes)} commute "
                    f"classes; pass cls= explicitly")
            cls = classes[0]
        return self._declare(
            shared, Suprema(reads=0, writes=max_ops, updates=0, commutes=cls))

    @staticmethod
    def _commute_classes(shared: SharedObject) -> Dict[str, str]:
        fn = getattr(shared, "commute_classes", None)
        return fn() if fn is not None else {}

    # ------------------------------------------------------------------ #
    # Start (§2.8.1)                                                     #
    # ------------------------------------------------------------------ #
    def begin(self) -> None:
        """Acquire private versions atomically; kick off read-only buffering."""
        if self._started:
            raise IllegalState("transaction already started")
        self._started = True
        self._terminated = False
        # Failover hook (before any version lock): transports with replica
        # chains re-point each access at a promoted follower when its
        # primary died — so dispense domains below are computed against
        # live nodes. In-process shared objects have no such hook.
        for a in self._order:
            ensure = getattr(a.shared, "ensure_primary", None)
            if ensure is not None:
                ensure()
        if _txtrace.enabled:
            self._obs_t0 = _txtrace.current().now()
        try:
            if _txtrace.enabled:
                t0 = _txtrace.current().now()
                dispense_for(self._order)
                self._obs_span("dispense", t0,
                               detail=f"objs={len(self._order)}")
            else:
                dispense_for(self._order)
        except BaseException:
            # Partial start (a remote node died mid-dispense): abandon the
            # versions that were dispensed — skipped in chain order so
            # successors on the surviving nodes unwedge without bypassing
            # live predecessors — and close the transaction.
            for a in self._order:
                if a.pv:
                    try:
                        a.abandon()
                    except TransactionError:
                        pass   # that node is gone; §3.4 cleans up there
            for a in self._order:
                a.finish_session()
            self._terminated = True
            if _txtrace.enabled:
                self._obs_span("txn", self._obs_t0, detail="abort",
                               sev=_txtrace.WARN)
            raise
        # §2.7/§2.8.1: asynchronously snapshot-and-release read-only
        # objects. Remote transports already fired these kickoffs inside
        # the dispense round trip (release_task set); only the in-process
        # domain still needs its tasks spawned here.
        for a in self._order:
            if (a.sup.read_only and a.sup.reads > 0
                    and a.release_task is None):
                a.spawn_ro_buffer(self._gate_kind)

    @property
    def _gate_kind(self) -> str:
        """Access gate — or termination gate for irrevocable txns (§2.4)."""
        return "termination" if self.irrevocable else "access"

    # ------------------------------------------------------------------ #
    # Operation dispatch                                                  #
    # ------------------------------------------------------------------ #
    def _invoke(self, shared: SharedObject, method: str, args: tuple,
                kwargs: dict) -> Any:
        if self._terminated:
            raise IllegalState("transaction already terminated")
        if not self._started:
            raise IllegalState("transaction not started; call begin()/start()")
        shared.check_reachable()
        a = self._accesses[shared]
        if a.sup.commutes is not None:
            return self._commute(a, shared, method, args, kwargs)
        mode = shared.mode_of(method)
        self._check_supremum(a, mode)
        try:
            if mode is Mode.READ:
                v = self._read(a, method, args, kwargs)
                self.stats.reads += 1
            elif mode is Mode.WRITE:
                v = self._write(a, method, args, kwargs)
                self.stats.writes += 1
            else:
                v = self._update(a, method, args, kwargs)
                self.stats.updates += 1
        except InstanceInvalidated as e:
            # Remote transport: the home node detected the invalidation
            # (in-process, _validity_check raises before the operation).
            self._force_abort(str(e))
        # heartbeat: only an actual holder (past the access condition and
        # not yet released) counts for the §3.4 failure detector
        a.note_contact()
        return v

    # -- commute-restricted invocation (DESIGN.md §12) -----------------------
    def _commute(self, a: ObjectAccess, shared: SharedObject, method: str,
                 args: tuple, kwargs: dict) -> None:
        """Buffer one commuting delta. Commute methods are write-only by
        declaration (`@access(Mode.WRITE, commutes=...)`), so the value is
        always ``None``; only methods of the DECLARED class are legal —
        anything else would break the no-coordination promise."""
        ccls = getattr(shared, "commute_of", lambda m: None)(method)
        if ccls != a.sup.commutes:
            raise IllegalState(
                f"method {shared.name}.{method} is not in this access's "
                f"declared commute class {a.sup.commutes!r} (got {ccls!r})")
        self._check_supremum(a, Mode.WRITE)
        a.record_commute(method, args, kwargs)
        a.wc += 1
        self.stats.writes += 1
        a.note_contact()
        return None

    def _check_supremum(self, a: ObjectAccess, mode: Mode) -> None:
        if a.count_for(mode) + 1 > a.sup_for(mode):
            self._force_abort(
                f"supremum violation: {mode.value} #{a.count_for(mode) + 1} on "
                f"{a.shared.name!r} exceeds bound {a.sup_for(mode)}",
                exc=SupremumViolation)

    # -- read (§2.8.2) -------------------------------------------------------
    def _read(self, a: ObjectAccess, method: str, args: tuple, kwargs: dict) -> Any:
        if a.sup.read_only or a.release_task is not None:
            # Read-only buffering, or released asynchronously after last
            # write: wait the task, read from the home-node buffer.
            a.join_release_task()
            self._validity_check()
            a.rc += 1
            return a.buf_call(method, args, kwargs)
        if a.released and a.buf is not None:
            # Released synchronously after last write/update.
            self._validity_check()
            a.rc += 1
            return a.buf_call(method, args, kwargs)
        if not a.holds_access:
            # First direct access: gate wait + checkpoint + log apply +
            # the read itself, fused into one home-node operation.
            blocked, v = a.open_and_call(self._gate_kind, self.wait_timeout,
                                         method, args, kwargs, modifies=False,
                                         validity=self._validity_check)
            if blocked:
                self.stats.waits += 1
        else:
            self._validity_check()
            v = a.raw_call(method, args, kwargs, modifies=False)
        a.rc += 1
        if a.all_suprema_met():   # last operation of any kind: release (§2.8.2)
            a.release()
        return v

    # -- update (§2.8.3) -----------------------------------------------------
    def _update(self, a: ObjectAccess, method: str, args: tuple, kwargs: dict) -> Any:
        if not a.holds_access:
            blocked, v = a.open_and_call(self._gate_kind, self.wait_timeout,
                                         method, args, kwargs, modifies=True,
                                         validity=self._validity_check)
            if blocked:
                self.stats.waits += 1
        else:
            self._validity_check()
            v = a.raw_call(method, args, kwargs, modifies=True)
        a.uc += 1
        if a.writes_updates_done():
            # No further writes/updates: buffer for trailing local reads, release.
            a.snapshot_and_release()
        return v

    # -- write (§2.8.4) ------------------------------------------------------
    def _write(self, a: ObjectAccess, method: str, args: tuple, kwargs: dict) -> Any:
        if a.holds_access:
            # Preceding reads/updates hold the object: operate directly.
            # Pure writes are value-less (the log-buffered path below always
            # returned None), so this path returns None too — which lets the
            # remote transport pipeline trailing writes as one-ways.
            self._validity_check()
            a.write_held(method, args, kwargs)
            a.wc += 1
            if a.writes_updates_done():
                # Paper §2.8.4 says "cloned to st"; that must be buf (see module doc).
                a.snapshot_and_release()
            return None
        # No preceding reads/updates: log-buffer the write, no synchronization.
        a.record_write(method, args, kwargs)
        a.wc += 1
        if a.wc == a.sup.writes and a.sup.updates == 0:
            # Final write (and no updates will follow): asynchronous apply+release.
            a.spawn_lastwrite_apply(self._gate_kind)
        return None

    # -- operation fusion (§2.8 / DESIGN.md §3.1 v3) -------------------------
    def invoke_many(self, proxy: Union[TxProxy, SharedObject, str],
                    ops: List[tuple]) -> List[Any]:
        """Invoke a run of operations against ONE object with *exactly*
        sequential semantics — as if each had been called through the proxy
        in order — fusing consecutive plain direct calls on a held remote
        object into single ``txn_call_batch`` RPCs (operation fusion).

        ``ops`` is ``[(method, args, kwargs), ...]``. The a-priori
        operation plan of the CF model is what makes runs visible before
        execution; anything the fusion rules cannot prove safe (opens,
        buffered reads, release transitions mid-run, in-process objects)
        falls back to the per-operation path, so behavior — including
        supremum aborts, early release points, and mid-run errors (prefix
        applied, suffix not) — is identical either way.
        """
        if isinstance(proxy, TxProxy):
            shared = object.__getattribute__(proxy, "_shared")
        else:
            shared = self._resolve(proxy)
        if self._terminated:
            raise IllegalState("transaction already terminated")
        if not self._started:
            raise IllegalState("transaction not started; call begin()/start()")
        out: List[Any] = []
        i = 0
        while i < len(ops):
            run, opening = self._fusable_run(shared, ops, i)
            if run <= 1:
                method, args, kwargs = ops[i]
                out.append(self._invoke(shared, method, args, kwargs))
                i += 1
            else:
                out.extend(self._invoke_fused(shared, ops[i:i + run],
                                              opening))
                i += run
        return out

    def _fusable_run(self, shared: SharedObject, ops: List[tuple],
                     i: int) -> tuple:
        """``(n, opening)``: length of the maximal fusable run of ``ops``
        starting at ``i``, and whether it begins with the §2.8.2-3 open
        (gate wait + checkpoint fused in — a read-modify-write hop on a
        fresh object is one RPC). A run is plain direct calls against one
        remote object, stopping *before* a supremum violation (the per-op
        path raises it with sequential semantics) and *after* the first op
        whose §2.8.2-4 post-transition fires (release at suprema /
        snapshot-and-release after the last write or update). Returns
        ``(1, False)`` whenever fusing cannot beat the per-op path: an
        in-process object, a released access or pending release task
        (buffered reads are already local), a leading read served by a
        local held-state copy (0 RPCs), or a leading log-buffered write
        (recorded client-side for free, §2.8.4)."""
        a = self._accesses[shared]
        if (a.dispense_domain is None or a.released
                or a.release_task is not None or a.sup.read_only
                or a.sup.commutes is not None):
            return 1, False   # commute deltas are client-side-free already
        opening = not a.holds_access
        first_mode = shared.mode_of(ops[i][0])
        if opening and first_mode is Mode.WRITE:
            return 1, False     # log-buffered write: free, no RPC to fuse
        if (not opening and first_mode is Mode.READ
                and getattr(a, "live_copy", None) is not None):
            return 1, False     # local (0-RPC) read: the per-op path is free
        rc, wc, uc = a.rc, a.wc, a.uc
        n = 0
        for method, _args, _kwargs in ops[i:]:
            mode = shared.mode_of(method)
            if mode is Mode.READ:
                if rc + 1 > a.sup.reads:
                    break
                rc += 1
            elif mode is Mode.WRITE:
                if wc + 1 > a.sup.writes:
                    break
                wc += 1
            else:
                if uc + 1 > a.sup.updates:
                    break
                uc += 1
            n += 1
            if (rc == a.sup.reads and wc == a.sup.writes
                    and uc == a.sup.updates):
                break       # all suprema met: release fires after this op
            if (mode is not Mode.READ and wc == a.sup.writes
                    and uc == a.sup.updates):
                break       # last write/update: snapshot+release fires
        return n, opening

    def _invoke_fused(self, shared: SharedObject, run_ops: List[tuple],
                      opening: bool) -> List[Any]:
        """Execute one fusable run as a single batched home-node operation
        (``opening`` folds the §2.8.2-3 gate wait + checkpoint in), then
        apply the sequential §2.8.2-4 bookkeeping: per-op counters and
        stats for the applied prefix, the original exception of a mid-run
        failure (suffix not executed), and the end-of-run release
        transition of the last op's mode."""
        a = self._accesses[shared]
        shared.check_reachable()
        modes = [shared.mode_of(m) for m, _a, _k in run_ops]
        calls = [(m, args, kwargs, mode is not Mode.READ)
                 for (m, args, kwargs), mode in zip(run_ops, modes)]
        self._validity_check()
        try:
            if opening:
                blocked, values, error = a.open_and_call_batch(
                    self._gate_kind, self.wait_timeout, calls)
                if blocked:
                    self.stats.waits += 1
            else:
                values, error = a.raw_call_batch(
                    calls, all_writes=all(m is Mode.WRITE for m in modes))
        except InstanceInvalidated as e:
            self._force_abort(str(e))
        last_mode = None
        for mode in modes[:len(values)]:
            if mode is Mode.READ:
                a.rc += 1
                self.stats.reads += 1
            elif mode is Mode.WRITE:
                a.wc += 1
                self.stats.writes += 1
            else:
                a.uc += 1
                self.stats.updates += 1
            last_mode = mode
        if error is not None:
            if isinstance(error, InstanceInvalidated):
                self._force_abort(str(error))
            raise error
        if last_mode is Mode.READ:
            if a.all_suprema_met():
                a.release()
        elif a.writes_updates_done():
            a.snapshot_and_release()
        a.note_contact()
        # Pure writes are value-less (see _write): mask their positions.
        return [None if mode is Mode.WRITE else v
                for v, mode in zip(values, modes)]

    # -- shared helpers --------------------------------------------------------
    def _validity_check(self) -> None:
        """Force an abort as soon as any observed instance was invalidated (§2.3)."""
        for a in self._order:
            if not a.valid():
                self._force_abort(
                    f"object {a.shared.name!r} was invalidated by a cascading abort")

    def _force_abort(self, msg: str, exc: type = AbortError) -> None:
        self._do_abort()
        self.stats.aborts += 1
        err = exc(msg) if exc is SupremumViolation else exc(msg, forced=True)
        raise err

    # ------------------------------------------------------------------ #
    # Commit (§2.8.5)                                                    #
    # ------------------------------------------------------------------ #
    def commit(self) -> None:
        if not _txtrace.enabled:
            self._commit_impl()
            return
        t0 = _txtrace.current().now()
        try:
            self._commit_impl()
        except BaseException:
            self._obs_span("commit", t0, detail="abort", sev=_txtrace.WARN)
            raise
        self._obs_span("commit", t0, detail="ok")
        self._obs_span("txn", self._obs_t0, detail="commit")

    def _commit_impl(self) -> None:
        if self._terminated:
            raise IllegalState("transaction already terminated")
        if not self._started:
            raise IllegalState("transaction not started")
        # 1. Wait for extant asynchronous tasks.
        task_error: Optional[BaseException] = None
        for a in self._order:
            try:
                a.join_release_task()
            except TransactionError as e:
                task_error = e
        if task_error is not None:
            self._do_abort()
            self.stats.aborts += 1
            raise AbortError(f"asynchronous task failed: {task_error}", forced=True)
        groups = self._domain_groups()
        try:
            if len(groups) == 1:
                # Single dispense domain: steps 2-5 are one unit (one RPC
                # on a remote transport) — the validation verdict needs no
                # cross-domain gather before termination.
                (accs,) = groups.values()
                blocked, ok = accs[0].commit_solo_async(
                    accs, self.wait_timeout).result()
                self.stats.waits += blocked
            else:
                remote = sorted(
                    ((dom, accs) for dom, accs in groups.items()
                     if dom is not None), key=lambda kv: kv[0])
                domains = [accs for _dom, accs in remote]
                local = groups.get(None)
                chain_fn = (getattr(domains[0][0], "commit_chain_async",
                                    None) if domains else None)
                if chain_fn is not None:
                    # Chained commit decision (DESIGN.md §8): validate the
                    # in-process group first (steps 2-4, zero messages),
                    # then hand the WHOLE remote commit — waves, decision,
                    # termination — to the first remote node in global
                    # domain order as ONE RPC. The commit/abort decision is
                    # made server-side: a client crash after that send can
                    # no longer strand a partially terminated commit (the
                    # §3.4 step-5 window, CLOSED).
                    ok = True
                    if local is not None:
                        blocked, ok = local[0].commit_wave1_async(
                            local, self.wait_timeout).result()
                        self.stats.waits += blocked
                    if ok:
                        if len(domains) == 1:
                            # One remote domain left: its verdict is local
                            # to it — steps 2-5 in one solo RPC.
                            blocked, ok = domains[0][0].commit_solo_async(
                                domains[0], self.wait_timeout).result()
                        else:
                            blocked, ok = chain_fn(
                                domains, self.wait_timeout).result()
                        self.stats.waits += blocked
                else:
                    # 2-4. One scatter-gathered wave per dispense domain:
                    # wait the commit condition, checkpoint untouched
                    # objects / apply left-over logs / release, validate —
                    # a single RPC per remote node, all nodes proceeding
                    # concurrently. (Releasing one node's objects before
                    # another node's commit condition passed is safe: step
                    # 3 released before step 4 validated already, and a
                    # later abort restores + bumps epochs exactly as
                    # before.)
                    wave1 = [accs[0].commit_wave1_async(accs,
                                                        self.wait_timeout)
                             for accs in groups.values()]
                    ok = True
                    for f in wave1:
                        blocked, valid = f.result()
                        self.stats.waits += blocked
                        ok = ok and valid
            if not ok:
                self._do_abort()
                self.stats.aborts += 1
                raise AbortError(
                    "commit-time validation failed (cascading abort)",
                    forced=True)
            if len(groups) > 1:
                # 5. Terminate: advance ltv on every object — only after
                # every domain's validation verdict is in. Domains the
                # chained decision already terminated server-side are
                # skipped (their accesses are marked); in practice that
                # leaves the in-process group, finished here at zero
                # message cost.
                ffuts = [accs[0].finish_batch_async(accs)
                         for accs in groups.values()
                         if not all(a.terminated for a in accs)]
                for f in ffuts:
                    f.result()
            # Final sync point: any deferred error of a pipelined one-way
            # op (early release notifications etc.) surfaces before the
            # commit is reported successful.
            for accs in groups.values():
                accs[0].raise_deferred()
        except TimeoutError as e:
            # A predecessor never terminated (e.g. crashed with no monitor):
            # leaving our objects unreleased would wedge every successor, so
            # route through the abort path like _do_abort does.
            self._do_abort()
            self.stats.aborts += 1
            raise AbortError(f"commit condition timed out: {e}",
                             forced=True) from e
        except InstanceInvalidated as e:
            self._force_abort(str(e))
        except AbortError:
            raise               # rollback already performed above
        except TransactionError:
            # A home node died mid-commit (RemoteObjectFailure etc.): roll
            # back the surviving objects before surfacing it — leaving them
            # unreleased would wedge every successor (§3.4).
            self._do_abort()
            self.stats.aborts += 1
            raise
        for a in self._order:
            a.finish_session()
        self._terminated = True

    # ------------------------------------------------------------------ #
    # Abort (§2.8.6) and retry                                            #
    # ------------------------------------------------------------------ #
    def abort(self) -> None:
        """Manual abort (Fig. 9). Raises AbortError to unwind the atomic block."""
        self._do_abort()
        self.stats.aborts += 1
        raise AbortError("transaction aborted manually", forced=False)

    def retry(self) -> None:
        """Manual retry: abort, then signal ``start`` to re-run the block."""
        self._do_abort()
        self.stats.retries += 1
        raise RetrySignal("transaction retry requested")

    def _domain_groups(self) -> Dict[Optional[tuple], List[ObjectAccess]]:
        """Accesses grouped by dispense domain (one group per remote node,
        plus the in-process group), remote domains first: issuing a wave
        over the groups in this order sends every remote (non-blocking)
        RPC before the in-process group's Completed executes-at-issue —
        otherwise a mixed-transport commit would serialize the local wait
        in front of the remote ones instead of overlapping them."""
        groups: Dict[Optional[tuple], List[ObjectAccess]] = {}
        for a in self._order:
            groups.setdefault(a.dispense_domain, []).append(a)
        if None in groups:
            groups[None] = groups.pop(None)   # move in-process group last
        return groups

    def _do_abort(self) -> None:
        if self._terminated:
            return
        # 1. Wait for extant tasks (they may still be mutating state).
        for a in self._order:
            try:
                a.join_release_task()
            except TransactionError:
                pass
        # 2. Wait for the commit condition per object (issued per dispense
        # domain, then awaited — remote waits overlap across nodes).
        waits = []
        for accs in self._domain_groups().values():
            try:
                waits.append(accs[0].wait_termination_batch_async(
                    accs, self.wait_timeout, best_effort=True))
            except (TimeoutError, TransactionError):
                pass  # predecessor crashed, or our home node/session is gone
        for w in waits:
            try:
                w.result()
            except (TimeoutError, TransactionError):
                pass  # (§3.4) — either way the monitor machinery cleans up
        # 3. Restore modified objects from their checkpoints,
        # oldest-restore-wins; per-node batches in one concurrent wave.
        # Already-terminated accesses are skipped (partial commit step 5
        # before a later object's node died): a successor may have
        # committed on the object since — restoring would erase its writes.
        groups = {dom: [a for a in accs if not a.terminated]
                  for dom, accs in self._domain_groups().items()}
        rfuts = []
        for accs in groups.values():
            if not accs:
                continue
            try:
                rfuts.append(accs[0].rollback_batch_async(accs))
            except TransactionError:
                pass  # home node unreachable/expired: its monitor restores
        for f in rfuts:
            try:
                f.result()
            except TransactionError:
                pass
        # 4. Release and terminate every object (best-effort per node).
        ffuts = []
        for accs in groups.values():
            if not accs:
                continue
            try:
                ffuts.append(accs[0].finish_batch_async(accs,
                                                        best_effort=True))
            except TransactionError:
                pass  # home node unreachable/expired: self-releases there
        for f in ffuts:
            try:
                f.result()
            except TransactionError:
                pass
        for a in self._order:
            a.finish_session()
        self._terminated = True
        if _txtrace.enabled:
            self._obs_instant("abort", sev=_txtrace.WARN)
            self._obs_span("txn", self._obs_t0, detail="abort",
                           sev=_txtrace.WARN)

    # ------------------------------------------------------------------ #
    # start(): run an atomic block with commit/abort/retry handling       #
    # ------------------------------------------------------------------ #
    def start(self, body: Callable[["Transaction"], Any], *,
              max_retries: int = 64) -> Any:
        """Run ``body(self)``; commit on fall-through (Fig. 9 semantics).

        ``retry()`` re-runs the block under a fresh transaction incarnation
        (new private versions, same declared access set). Manual and forced
        aborts propagate as :class:`AbortError` after rollback completes.
        """
        attempts = 0
        while True:
            attempts += 1
            if not self._started:
                self.begin()
            try:
                result = body(self)
            except RetrySignal:
                if attempts > max_retries:
                    raise AbortError("retry limit exceeded", forced=True) from None
                self._reincarnate()
                continue
            except AbortError:
                raise  # rollback already performed by abort()/_force_abort
            except BaseException:
                # Any exception escaping the block — including remote-object
                # failures (§3.4) — aborts the transaction (§3.2).
                if not self._terminated:
                    self._do_abort()
                    self.stats.aborts += 1
                raise
            if not self._terminated:
                self.commit()
            return result

    def _reincarnate(self) -> None:
        """Rebuild per-object records for a retry: fresh versions, same set."""
        fresh: List[ObjectAccess] = []
        mapping: Dict[SharedObject, ObjectAccess] = {}
        for a in self._order:
            na = a.shared.make_access(self, a.sup)
            fresh.append(na)
            mapping[a.shared] = na
        self._order = fresh
        self._accesses = mapping
        self.incarnation += 1
        self._started = False
        self._terminated = False
        self.begin()

    # -- context-manager sugar -------------------------------------------------
    def __enter__(self) -> "Transaction":
        if not self._started:
            self.begin()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            if not self._terminated:
                self.commit()
            return False
        if not self._terminated and not isinstance(exc, TransactionError):
            self._do_abort()
            self.stats.aborts += 1
        return False
