"""TFA-style optimistic baseline (HyFlow2 stand-in, paper §4.1/§5).

An in-process realization of the Transaction Forwarding Algorithm family
(TFA [18] / DTL2): a global version clock, per-object version stamps,
transaction-local read/write buffering, *transaction forwarding* (advancing
the transaction's start stamp after revalidating the read set when a newer
object version is encountered), commit-time lock-validate-writeback, and
abort/retry with backoff. Opaque, but irrevocable operations inside the
atomic block may re-execute on retry — exactly the deficiency the paper's
pessimistic approach avoids (§2.4, Fig. 13).
"""
from __future__ import annotations

import itertools
import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

from .api import Mode, OpStats, TransactionError
from .buffers import snapshot_state
from .registry import Node, Registry, SharedObject

_txn_ids = itertools.count(1)


class _GlobalClock:
    def __init__(self):
        self._v = 0
        self._lock = threading.Lock()

    def read(self) -> int:
        return self._v

    def advance(self) -> int:
        with self._lock:
            self._v += 1
            return self._v


CLOCK = _GlobalClock()


class _TfaMeta:
    """Per-object optimistic metadata: version stamp + commit lock."""

    __slots__ = ("version", "lock", "owner")

    def __init__(self):
        self.version = 0
        self.lock = threading.Lock()
        self.owner: Optional[int] = None


class _MetaTable:
    def __init__(self):
        self._lock = threading.Lock()
        self._meta: Dict[SharedObject, _TfaMeta] = {}

    def get(self, shared: SharedObject) -> _TfaMeta:
        with self._lock:
            return self._meta.setdefault(shared, _TfaMeta())


META = _MetaTable()


class TfaAbort(TransactionError):
    """Internal conflict signal: triggers a retry loop iteration."""


class _TfaProxy:
    __slots__ = ("_txn", "_shared")

    def __init__(self, txn: "TfaTransaction", shared: SharedObject):
        object.__setattr__(self, "_txn", txn)
        object.__setattr__(self, "_shared", shared)

    def __getattr__(self, method: str) -> Callable[..., Any]:
        txn = object.__getattribute__(self, "_txn")
        shared = object.__getattribute__(self, "_shared")

        def call(*args: Any, **kwargs: Any) -> Any:
            return txn._invoke(shared, method, args, kwargs)

        return call


class TfaTransaction:
    """Optimistic transaction: buffered execution + commit-time validation."""

    def __init__(self, registry: Optional[Registry] = None, *,
                 client_node: Optional[Node] = None,
                 max_retries: int = 10_000):
        self.id = next(_txn_ids)
        self.registry = registry
        self.client_node = client_node
        self.max_retries = max_retries
        self.stats = OpStats()
        self._declared: List[SharedObject] = []
        self._reset()

    def _reset(self) -> None:
        self.rv = CLOCK.read()
        # shared -> (local deep copy to run operations on, version at copy time)
        self._workspace: Dict[SharedObject, Tuple[Any, int]] = {}
        self._read_set: Dict[SharedObject, int] = {}
        self._write_set: Dict[SharedObject, Any] = {}

    # -- preamble (declarations are advisory for optimistic execution) --------
    def _declare(self, obj: Union[SharedObject, str]) -> _TfaProxy:
        shared = self.registry.locate(obj) if isinstance(obj, str) else obj
        self._declared.append(shared)
        return _TfaProxy(self, shared)

    def reads(self, obj, *_sup) -> _TfaProxy:
        return self._declare(obj)

    writes = reads
    updates = reads
    accesses = reads

    def begin(self) -> None:
        self._reset()

    # -- operation execution ----------------------------------------------------
    def _open(self, shared: SharedObject) -> Any:
        """Open an object into the transaction workspace (DF model: the state
        is fetched to the client; operations run on the local copy)."""
        if shared in self._workspace:
            return self._workspace[shared][0]
        meta = META.get(shared)
        if meta.lock.locked() and meta.owner != self.id:
            raise TfaAbort(f"{shared.name} locked by a committing transaction")
        version = meta.version
        if version > self.rv:
            # Transaction forwarding: revalidate the read set, advance rv.
            self._validate_read_set()
            self.rv = CLOCK.read()
        shared.check_reachable()
        # DF model: the state is fetched to the client. Uses the snapshot
        # protocol (buffers.snapshot_state) so the optimistic baseline pays
        # the same per-object copy cost as the pessimistic frameworks.
        local = snapshot_state(shared.holder.obj)
        self._workspace[shared] = (local, version)
        self._read_set[shared] = version
        return local

    def _validate_read_set(self) -> None:
        for shared, seen in self._read_set.items():
            meta = META.get(shared)
            if meta.version != seen or (meta.lock.locked() and meta.owner != self.id):
                raise TfaAbort(f"read-set validation failed on {shared.name}")

    def _invoke(self, shared: SharedObject, method: str, args: tuple,
                kwargs: dict) -> Any:
        mode = shared.mode_of(method)
        local = self._open(shared)
        if shared.node is not None:
            shared.node.simulate_network(self.client_node)
        v = getattr(local, method)(*args, **kwargs)
        if mode is Mode.READ:
            self.stats.reads += 1
        else:
            self._write_set[shared] = local
            if mode is Mode.WRITE:
                self.stats.writes += 1
            else:
                self.stats.updates += 1
        return v

    # -- commit -----------------------------------------------------------------
    def commit(self) -> None:
        locked: List[_TfaMeta] = []
        try:
            for shared in sorted(self._write_set, key=lambda s: s.header.uid):
                meta = META.get(shared)
                if not meta.lock.acquire(blocking=False):
                    self.stats.waits += 1        # actually contended
                    if not meta.lock.acquire(timeout=1.0):
                        raise TfaAbort(f"commit lock timeout on {shared.name}")
                meta.owner = self.id
                locked.append(meta)
            self._validate_read_set()
            wv = CLOCK.advance()
            for shared, local in self._write_set.items():
                shared.holder.obj = local
                META.get(shared).version = wv
        finally:
            for meta in locked:
                meta.owner = None
                meta.lock.release()

    def start(self, body: Callable[["TfaTransaction"], Any]) -> Any:
        """Optimistic retry loop: execute, validate, commit; abort → re-execute.

        Every retry re-runs the entire atomic block — including any
        irrevocable operations in it.
        """
        attempt = 0
        while True:
            attempt += 1
            self.begin()
            try:
                result = body(self)
                self.commit()
                return result
            except TfaAbort:
                self.stats.aborts += 1
                self.stats.retries += 1
                if attempt >= self.max_retries:
                    raise
                # randomized backoff, grows with contention
                time.sleep(random.uniform(0, 0.0005) * min(attempt, 32))
